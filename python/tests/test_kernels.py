"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py is the
core correctness signal for the whole stack (the same kernels lower into
the AOT HLO the Rust runtime executes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blocked_matmul, flash_attention
from compile.kernels import ref


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------
# blocked_matmul
# ---------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shapes(m, k, n, seed):
    a = rand(seed, (m, k), jnp.float32)
    b = rand(seed + 1, (k, n), jnp.float32)
    got = blocked_matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = rand(7, (64, 96), dtype)
    b = rand(8, (96, 32), dtype)
    got = blocked_matmul(a, b)
    assert got.dtype == dtype
    want = np.array(a, np.float32) @ np.array(b, np.float32)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.array(got, np.float32), want, rtol=tol, atol=tol * np.abs(want).max()
    )


@pytest.mark.parametrize("block", [16, 128, 999])
def test_matmul_block_size_invariance(block):
    a = rand(9, (80, 120), jnp.float32)
    b = rand(10, (120, 72), jnp.float32)
    got = blocked_matmul(a, b, block_m=block, block_n=block, block_k=block)
    np.testing.assert_allclose(
        np.array(got), np.array(a @ b), rtol=2e-4, atol=2e-4
    )


def test_matmul_identity():
    a = rand(11, (32, 32), jnp.float32)
    eye = jnp.eye(32, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.array(blocked_matmul(a, eye)), np.array(a), rtol=1e-5, atol=1e-5
    )


def test_matmul_rejects_mismatched_k():
    a = rand(1, (8, 16), jnp.float32)
    b = rand(2, (17, 8), jnp.float32)
    with pytest.raises(AssertionError):
        blocked_matmul(a, b)


# ---------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 8),
    s_pow=st.integers(4, 8),  # seq = 16..256
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_shapes(h, s_pow, d, seed):
    s = 2**s_pow
    q = rand(seed, (h, s, d), jnp.float32)
    k = rand(seed + 1, (h, s, d), jnp.float32)
    v = rand(seed + 2, (h, s, d), jnp.float32)
    got = flash_attention(q, k, v, block_q=32, block_kv=32)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    length=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_padding_mask(length, seed):
    h, s, d = 2, 128, 16
    q = rand(seed, (h, s, d), jnp.float32)
    k = rand(seed + 1, (h, s, d), jnp.float32)
    v = rand(seed + 2, (h, s, d), jnp.float32)
    la = jnp.array(length, jnp.int32)
    got = flash_attention(q, k, v, length=la)
    want = ref.attention_ref(q, k, v, causal=True, length=la)
    # Only the valid rows are contractually defined.
    np.testing.assert_allclose(
        np.array(got)[:, :length], np.array(want)[:, :length], rtol=2e-5, atol=2e-5
    )


def test_attention_noncausal():
    h, s, d = 3, 64, 32
    q, k, v = (rand(i, (h, s, d), jnp.float32) for i in range(3))
    got = flash_attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=2e-5)


def test_attention_block_shape_invariance():
    h, s, d = 2, 128, 16
    q, k, v = (rand(i + 10, (h, s, d), jnp.float32) for i in range(3))
    a = flash_attention(q, k, v, block_q=32, block_kv=64)
    b = flash_attention(q, k, v, block_q=128, block_kv=16)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-5, atol=2e-5)


def test_attention_bf16():
    h, s, d = 2, 64, 32
    q, k, v = (rand(i + 20, (h, s, d), jnp.bfloat16) for i in range(3))
    got = flash_attention(q, k, v)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.array(got, np.float32), np.array(want, np.float32), rtol=5e-2, atol=5e-2
    )
    assert got.dtype == jnp.bfloat16


def test_attention_first_row_attends_self_only():
    # Causal row 0 output = v[0] exactly (softmax over one element).
    h, s, d = 1, 32, 8
    q, k, v = (rand(i + 30, (h, s, d), jnp.float32) for i in range(3))
    got = flash_attention(q, k, v)
    np.testing.assert_allclose(
        np.array(got)[:, 0], np.array(v)[:, 0], rtol=1e-5, atol=1e-5
    )


def test_decode_attention_ref_matches_full():
    # Single-query oracle must agree with the full attention at that row.
    h, s, d = 2, 64, 16
    q, k, v = (rand(i + 40, (h, s, d), jnp.float32) for i in range(3))
    pos = 17
    full = ref.attention_ref(q, k, v, causal=True)
    kc = jnp.transpose(k, (1, 0, 2))  # [s, h, d]
    vc = jnp.transpose(v, (1, 0, 2))
    single = ref.decode_attention_ref(q[:, pos], kc, vc, jnp.array(pos))
    np.testing.assert_allclose(
        np.array(single), np.array(full)[:, pos], rtol=2e-5, atol=2e-5
    )


def test_attention_numerically_stable_large_logits():
    """Online softmax must not overflow with large score magnitudes."""
    h, s, d = 2, 64, 16
    q = 30.0 * rand(51, (h, s, d), jnp.float32)
    k = 30.0 * rand(52, (h, s, d), jnp.float32)
    v = rand(53, (h, s, d), jnp.float32)
    got = np.array(flash_attention(q, k, v))
    assert np.isfinite(got).all()
    want = np.array(ref.attention_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_attention_length_one():
    """Degenerate valid-length: only position 0 defined."""
    h, s, d = 1, 32, 8
    q, k, v = (rand(i + 60, (h, s, d), jnp.float32) for i in range(3))
    got = flash_attention(q, k, v, length=jnp.array(1, jnp.int32))
    np.testing.assert_allclose(
        np.array(got)[:, 0], np.array(v)[:, 0], rtol=1e-5, atol=1e-5
    )
