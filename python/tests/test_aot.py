"""AOT pipeline: HLO text + manifest + parameter blob integrity."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

MICRO = M.TransformerConfig(
    name="micro", n_layers=1, d_model=32, n_heads=2, d_ff=64, max_seq=32
)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.lower_variant(MICRO, seed=1, out_dir=out)
    return out, entry


def test_hlo_text_is_parseable_hlo(lowered):
    out, entry = lowered
    for key in ("prefill_hlo", "decode_hlo"):
        text = (out / entry[key]).read_text()
        assert text.startswith("HloModule"), key
        assert "ENTRY" in text
        # Tuple return (rust unwraps with to_tuple).
        assert "tuple(" in text or "ROOT" in text


def test_params_blob_roundtrip(lowered):
    out, entry = lowered
    blob = np.fromfile(out / entry["params_bin"], dtype="<f4")
    assert blob.size == entry["param_count"]
    params = M.init_params(MICRO, seed=1)
    flat = np.concatenate([np.asarray(p).ravel() for p in params])
    np.testing.assert_array_equal(blob, flat.astype("<f4"))


def test_manifest_entry_shapes(lowered):
    _, entry = lowered
    spec = M.param_spec(MICRO)
    assert len(entry["params"]) == len(spec)
    for rec, (name, shape) in zip(entry["params"], spec):
        assert rec["name"] == name
        assert tuple(rec["shape"]) == shape
    assert entry["head_dim"] == MICRO.head_dim


def test_hlo_executes_via_jax_roundtrip(lowered):
    """The lowered prefill HLO must produce the same logits as eager
    execution — executed through jax's own CPU client from the HLO text's
    source computation."""
    params = M.init_params(MICRO, seed=1)
    s = MICRO.max_seq
    tokens = jnp.zeros((s,), jnp.int32).at[:3].set(jnp.array([256, 1, 2]))
    length = jnp.array(3, jnp.int32)
    eager_logits, _, _ = M.prefill(MICRO, params, tokens, length)
    jit_logits, _, _ = jax.jit(M.prefill_fn(MICRO))(*params, tokens, length)
    np.testing.assert_allclose(
        np.array(eager_logits), np.array(jit_logits), rtol=1e-5, atol=1e-5
    )


def test_repo_manifest_if_built():
    """When `make artifacts` has run, validate the real manifest."""
    path = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
    if not path.exists():
        pytest.skip("artifacts/ not built")
    manifest = json.loads(path.read_text())
    assert manifest["format"] == 1
    names = {v["name"] for v in manifest["variants"]}
    assert {"device_sm", "server_md"} <= names
    for v in manifest["variants"]:
        base = path.parent
        assert (base / v["prefill_hlo"]).exists()
        assert (base / v["decode_hlo"]).exists()
        blob = np.fromfile(base / v["params_bin"], dtype="<f4")
        assert blob.size == v["param_count"]


def test_hlo_has_no_elided_constants(lowered):
    """print_large_constants must keep baked weights in the text — the
    0.5.1 parser silently reads elided `{...}` constants as zeros."""
    out, entry = lowered
    for key in ("prefill_hlo", "decode_hlo"):
        text = (out / entry[key]).read_text()
        assert "constant({...})" not in text, key
        # Metadata must be stripped (the old parser rejects
        # source_end_line attributes emitted by jax 0.8 printers).
        assert "source_end_line" not in text, key


def test_tokenizer_constants_match_rust_defaults():
    """model.py's vocab constants are the ABI shared with
    rust/src/runtime/tokenizer.rs."""
    assert M.BOS_ID == 256
    assert M.EOS_ID == 257
    assert M.VOCAB == 512
