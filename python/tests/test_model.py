"""L2 correctness: transformer shapes, prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.TransformerConfig(
    name="tiny", n_layers=2, d_model=64, n_heads=2, d_ff=128, max_seq=64
)


@pytest.fixture(scope="module")
def tiny_params():
    return M.init_params(TINY, seed=3)


def test_param_spec_matches_init(tiny_params):
    spec = M.param_spec(TINY)
    assert len(spec) == len(tiny_params)
    for (name, shape), p in zip(spec, tiny_params):
        assert tuple(p.shape) == shape, name
    assert sum(int(np.prod(s)) for _, s in spec) == TINY.param_count()


def test_init_is_deterministic():
    a = M.init_params(TINY, seed=5)
    b = M.init_params(TINY, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.array(x), np.array(y))
    c = M.init_params(TINY, seed=6)
    assert any(not np.array_equal(np.array(x), np.array(y)) for x, y in zip(a, c))


def test_prefill_shapes(tiny_params):
    tokens = jnp.zeros((TINY.max_seq,), jnp.int32).at[:5].set(
        jnp.array([256, 72, 105, 33, 257])
    )
    logits, kc, vc = M.prefill(TINY, tiny_params, tokens, jnp.array(5, jnp.int32))
    assert logits.shape == (TINY.vocab,)
    assert kc.shape == (TINY.n_layers, TINY.max_seq, TINY.n_heads, TINY.head_dim)
    assert vc.shape == kc.shape
    assert np.isfinite(np.array(logits)).all()


def test_prefill_ignores_padding(tiny_params):
    """Logits must depend only on tokens[:length]."""
    base = jnp.zeros((TINY.max_seq,), jnp.int32).at[:4].set(jnp.array([1, 2, 3, 4]))
    noisy = base.at[10:20].set(99)
    l = jnp.array(4, jnp.int32)
    la, _, _ = M.prefill(TINY, tiny_params, base, l)
    lb, _, _ = M.prefill(TINY, tiny_params, noisy, l)
    np.testing.assert_allclose(np.array(la), np.array(lb), rtol=1e-5, atol=1e-5)


def test_decode_consistent_with_prefill(tiny_params):
    """decode_step at position L must equal prefill over L+1 tokens."""
    prompt = [256, 10, 20, 30]
    s = TINY.max_seq
    # Prefill over the 4-token prompt, then decode token 40 at position 4.
    tokens4 = jnp.zeros((s,), jnp.int32).at[:4].set(jnp.array(prompt))
    _, kc, vc = M.prefill(TINY, tiny_params, tokens4, jnp.array(4, jnp.int32))
    logits_step, _, _ = M.decode_step(
        TINY, tiny_params, jnp.array(40, jnp.int32), jnp.array(4, jnp.int32), kc, vc
    )
    # Ground truth: prefill over the 5-token sequence.
    tokens5 = jnp.zeros((s,), jnp.int32).at[:5].set(jnp.array(prompt + [40]))
    logits_full, _, _ = M.prefill(TINY, tiny_params, tokens5, jnp.array(5, jnp.int32))
    np.testing.assert_allclose(
        np.array(logits_step), np.array(logits_full), rtol=5e-4, atol=5e-4
    )


def test_reference_generate_is_deterministic(tiny_params):
    a = M.reference_generate(TINY, tiny_params, [256, 5, 6], 8)
    b = M.reference_generate(TINY, tiny_params, [256, 5, 6], 8)
    assert a == b
    assert len(a) == 8
    assert all(0 <= t < TINY.vocab for t in a)


def test_decode_updates_cache_at_pos(tiny_params):
    s = TINY.max_seq
    kc = jnp.zeros((TINY.n_layers, s, TINY.n_heads, TINY.head_dim))
    vc = jnp.zeros_like(kc)
    _, kc2, vc2 = M.decode_step(
        TINY, tiny_params, jnp.array(1, jnp.int32), jnp.array(7, jnp.int32), kc, vc
    )
    # Only position 7 changed.
    changed_k = np.any(np.array(kc2) != 0.0, axis=(0, 2, 3))
    assert changed_k[7]
    assert changed_k.sum() == 1
    changed_v = np.any(np.array(vc2) != 0.0, axis=(0, 2, 3))
    assert changed_v[7]


def test_variants_are_well_formed():
    for name, cfg in M.VARIANTS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.param_count() > 0
        assert cfg.vocab == M.VOCAB
    assert M.DEVICE_SM.param_count() < M.SERVER_MD.param_count()
