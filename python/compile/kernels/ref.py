"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy ops. pytest asserts allclose between the two
across shape/dtype sweeps; the reference is also what the L2 model uses on
paths that are not compute hot-spots (single-token decode attention).
"""

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    length: jax.Array | None = None,
) -> jax.Array:
    """Multi-head attention oracle.

    Args:
      q, k, v: [heads, seq, head_dim].
      causal: apply a causal mask.
      length: optional valid-length scalar; keys at positions >= length are
        masked out (padding).

    Returns:
      [heads, seq, head_dim] attention output.
    """
    h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    logits = jnp.einsum(
        "hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        logits = jnp.where(ki <= qi, logits, neg)
    if length is not None:
        ki = jnp.arange(s)[None, None, :]
        logits = jnp.where(ki < length, logits, neg)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array
) -> jax.Array:
    """Single-query attention against a KV cache.

    Args:
      q: [heads, head_dim] query for the token at position `pos`.
      k_cache, v_cache: [seq, heads, head_dim].
      pos: scalar int32 position of the query (attends to 0..=pos).

    Returns:
      [heads, head_dim].
    """
    s = k_cache.shape[0]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    logits = jnp.einsum(
        "hd,shd->hs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(s)[None, :] <= pos
    logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hs,shd->hd", w, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
