"""L1 Pallas kernel: Flash-style blocked causal attention.

TPU-oriented design (DESIGN.md §Hardware-Adaptation): the HBM↔VMEM
schedule is expressed through BlockSpecs — the grid iterates (head,
q-block) and each kernel invocation streams K/V for its head through VMEM
while maintaining the online-softmax running max/denominator in f32
scratch. On a real TPU the inner contractions map onto the MXU; here
`interpret=True` lowers the same program to plain HLO so the CPU PJRT
client can execute it (Mosaic custom-calls cannot run on CPU).

VMEM budget per grid step (f32 words):
  q block:       block_q × head_dim
  k, v (head):   2 × seq × head_dim
  accumulators:  block_q × head_dim + 2 × block_q
With the defaults (block_q=64, head_dim ≤ 128, seq ≤ 1024) this stays
well under a 16 MB VMEM budget; see EXPERIMENTS.md §Perf for the
utilization estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_q, block_kv, causal):
    """One (head, q-block) grid step with an online-softmax kv loop."""
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # [block_q, d]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    q = q * scale

    seq = k_ref.shape[0]
    n_kv = seq // block_kv
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # global q rows
    valid_len = len_ref[0]

    neg = jnp.finfo(jnp.float32).min

    def body(kv_i, carry):
        acc, m_prev, l_prev = carry
        k_blk = pl.load(
            k_ref, (pl.dslice(kv_i * block_kv, block_kv), slice(None))
        ).astype(jnp.float32)
        v_blk = pl.load(
            v_ref, (pl.dslice(kv_i * block_kv, block_kv), slice(None))
        ).astype(jnp.float32)
        s = q @ k_blk.T  # [block_q, block_kv] — MXU contraction on TPU
        k_pos = kv_i * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = k_pos[None, :] < valid_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, neg)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Guard fully-masked rows (exp(neg - neg) would be exp(0)).
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m0 = jnp.full((block_q,), neg, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)

    if causal:
        # Skip kv blocks entirely above the diagonal.
        last_kv = jnp.minimum(((qi + 1) * block_q + block_kv - 1) // block_kv, n_kv)
    else:
        last_kv = n_kv
    acc, _, l = jax.lax.fori_loop(0, last_kv, body, (acc0, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)  # padded rows produce zeros
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array | None = None,
    causal: bool = True,
    block_q: int = 64,
    block_kv: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Blocked causal attention.

    Args:
      q, k, v: [heads, seq, head_dim]; seq must be divisible by block_q
        and block_kv (pad upstream).
      length: scalar int32 valid length (keys >= length masked); defaults
        to seq.
      causal: apply the causal mask.
      interpret: MUST stay True for CPU execution (see module docstring).

    Returns:
      [heads, seq, head_dim], same dtype as q.
    """
    h, s, d = q.shape
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    if length is None:
        length = jnp.array(s, dtype=jnp.int32)
    len_arr = jnp.reshape(length.astype(jnp.int32), (1,))

    grid = (h, s // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_kv=block_kv, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1,), lambda hi, qi: (0,)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v, len_arr)
