"""L1 Pallas kernel: MXU-tiled blocked matmul.

Tiles (M, N, K) into MXU-native blocks with an f32 VMEM accumulator; the
K dimension is the innermost grid axis so the output block is revisited
and accumulated in place (`@pl.when` zero-initialises on the first K
step). With 128×128 blocks the VMEM footprint is
3 × 128 × 128 × 4 B ≈ 192 KB — far inside budget — and each step is one
native MXU tile contraction. `interpret=True` for CPU execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += (a @ b).astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is ≤ preferred (dims here are ≥1)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def blocked_matmul(
    a: jax.Array,
    b: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """C = A @ B with MXU-style tiling.

    Args:
      a: [M, K]; b: [K, N]. Block sizes self-adjust to divide the dims.

    Returns:
      [M, N] in a's dtype (f32 accumulation inside).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
