"""L1 Pallas kernels + pure-jnp reference oracles."""

from .attention import flash_attention
from .matmul import blocked_matmul
from . import ref

__all__ = ["flash_attention", "blocked_matmul", "ref"]
