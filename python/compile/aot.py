"""AOT pipeline: lower the L2 model to HLO text + parameter blobs.

For each model variant this emits into artifacts/:

  <variant>.prefill.hlo.txt   — HLO text of prefill(params..., tokens, length)
  <variant>.decode.hlo.txt    — HLO text of decode(params..., token, pos, kc, vc)
  <variant>.params.bin        — little-endian f32 parameter data, in
                                param_spec order, contiguous
  manifest.json               — shapes/ABI for the Rust runtime

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate's xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit
instruction ids; the text parser reassigns ids (see /opt/xla-example).

Python runs only at build time: `make artifacts` is a no-op when outputs
are newer than their inputs.
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text.

    return_tuple=True: xla_extension 0.5.1's PJRT returns the root as a
    single tuple buffer either way (no output flattening in this build —
    verified, return_tuple=False crashes its compiler), so the Rust side
    unwraps with to_tuple3(). print_large_constants=True keeps baked
    weights in the text (the default printer elides them to `{...}`,
    which the parser silently reads as zeros)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8 emits source_end_line/column metadata the 0.5.1 HLO parser
    # rejects; strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_variant(
    cfg: M.TransformerConfig,
    seed: int,
    out_dir: pathlib.Path,
    bake_params: bool = True,
) -> dict:
    """Lower one model variant; returns its manifest entry.

    bake_params=True closes the weights into the HLO as constants (§Perf:
    this PJRT build re-converts every literal argument per execute() call
    — ~4 MB/step for device_sm — so baking removes the dominant per-token
    host cost; the runtime then passes only (tokens, length) / (token,
    pos, caches))."""
    spec = M.param_spec(cfg)
    param_shapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]

    s = cfg.max_seq
    cache_shape = (cfg.n_layers, s, cfg.n_heads, cfg.head_dim)
    tokens = jax.ShapeDtypeStruct((s,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.ShapeDtypeStruct(cache_shape, jnp.float32)

    if bake_params:
        const_params = M.init_params(cfg, seed)
        prefill_fn = lambda t, l: M.prefill(cfg, const_params, t, l)  # noqa: E731
        decode_fn = lambda tok, p, kc, vc: M.decode_step(  # noqa: E731
            cfg, const_params, tok, p, kc, vc
        )
        prefill_lowered = jax.jit(prefill_fn).lower(tokens, scalar)
        decode_lowered = jax.jit(decode_fn).lower(scalar, scalar, cache, cache)
    else:
        prefill_lowered = jax.jit(M.prefill_fn(cfg)).lower(*param_shapes, tokens, scalar)
        decode_lowered = jax.jit(M.decode_fn(cfg)).lower(
            *param_shapes, scalar, scalar, cache, cache
        )

    prefill_path = out_dir / f"{cfg.name}.prefill.hlo.txt"
    decode_path = out_dir / f"{cfg.name}.decode.hlo.txt"
    prefill_path.write_text(to_hlo_text(prefill_lowered))
    decode_path.write_text(to_hlo_text(decode_lowered))

    # Parameter blob: contiguous f32 little-endian in spec order.
    params = M.init_params(cfg, seed)
    blob = b"".join(np.asarray(p, dtype="<f4").tobytes() for p in params)
    params_path = out_dir / f"{cfg.name}.params.bin"
    params_path.write_bytes(blob)

    return {
        "name": cfg.name,
        "baked_params": bake_params,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "vocab": cfg.vocab,
        "head_dim": cfg.head_dim,
        "seed": seed,
        "param_count": int(sum(int(np.prod(s)) for _, s in spec)),
        "params": [
            {"name": name, "shape": list(shape)} for name, shape in spec
        ],
        "prefill_hlo": prefill_path.name,
        "decode_hlo": decode_path.name,
        "params_bin": params_path.name,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--variants",
        default=",".join(M.VARIANTS),
        help="comma-separated variant names",
    )
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    entries = []
    for name in args.variants.split(","):
        cfg = M.VARIANTS[name]
        print(f"lowering {name}: {cfg.param_count():,} params ...", flush=True)
        entries.append(lower_variant(cfg, args.seed, out_dir))

    manifest = {
        "format": 1,
        "bos_id": M.BOS_ID,
        "eos_id": M.EOS_ID,
        "vocab": M.VOCAB,
        "variants": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir}/manifest.json with {len(entries)} variants")


if __name__ == "__main__":
    main()
