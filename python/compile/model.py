"""L2: decoder-only transformer LM in JAX, calling the L1 Pallas kernels.

This is the "model" half of the three-layer stack: a pre-norm transformer
with byte-level vocabulary whose *prefill* path routes attention through
the Pallas flash-attention kernel and its FFN through the Pallas blocked
matmul. The *decode* path is single-token work (matvecs) where a blocked
kernel has nothing to tile, so it uses the jnp reference ops.

Both entry points are pure functions over an explicit parameter list so
they AOT-lower cleanly (aot.py) and the Rust runtime can feed parameters
positionally:

  prefill(params..., tokens[S] i32, length[] i32)
      -> (logits[V], k_cache[L,S,H,Dh], v_cache[L,S,H,Dh])
  decode(params..., token[] i32, pos[] i32, k_cache, v_cache)
      -> (logits[V], k_cache, v_cache)
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import blocked_matmul, flash_attention
from .kernels import ref as kref

# Byte-level tokenizer: 256 bytes + BOS + EOS, padded to a lane-friendly
# table size. Must match rust/src/runtime/tokenizer.rs.
BOS_ID = 256
EOS_ID = 257
VOCAB = 512


@dataclass(frozen=True)
class TransformerConfig:
    """Model hyperparameters for one AOT variant."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    max_seq: int
    vocab: int = VOCAB

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        per_layer = 4 * self.d_model**2 + 2 * self.d_model * self.d_ff + 2 * self.d_model
        return (
            2 * self.vocab * self.d_model
            + self.max_seq * self.d_model
            + self.n_layers * per_layer
            + self.d_model  # final norm
        )


# The two serving variants: the "device" model is the small fast one, the
# "server" model the larger one (synthetic weights; see DESIGN.md).
DEVICE_SM = TransformerConfig(
    name="device_sm", n_layers=4, d_model=128, n_heads=4, d_ff=512, max_seq=256
)
SERVER_MD = TransformerConfig(
    name="server_md", n_layers=6, d_model=192, n_heads=6, d_ff=768, max_seq=256
)
VARIANTS = {c.name: c for c in (DEVICE_SM, SERVER_MD)}


def param_spec(cfg: TransformerConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the ABI between aot.py and Rust."""
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.max_seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_down", (cfg.d_ff, cfg.d_model)),
        ]
    spec += [
        ("ln_f", (cfg.d_model,)),
        ("unembed", (cfg.d_model, cfg.vocab)),
    ]
    return spec


def init_params(cfg: TransformerConfig, seed: int = 0) -> list[jax.Array]:
    """Deterministic synthetic weights (no pretrained weights offline)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return params


def _unpack(cfg: TransformerConfig, params: list[jax.Array]) -> dict:
    spec = param_spec(cfg)
    assert len(params) == len(spec), (len(params), len(spec))
    return {name: p for (name, _), p in zip(spec, params)}


def _rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _ffn_prefill(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    # Pallas blocked matmul on the [S, d]×[d, ff] hot path.
    h = blocked_matmul(x, w_up)
    h = jax.nn.gelu(h)
    return blocked_matmul(h, w_down)


def prefill(cfg: TransformerConfig, params: list[jax.Array], tokens: jax.Array,
            length: jax.Array):
    """Process a (padded) prompt; return next-token logits and KV caches.

    Args:
      tokens: [max_seq] int32, padded with zeros beyond `length`.
      length: scalar int32 valid prompt length (1..max_seq).

    Returns:
      logits: [vocab] for the position after the prompt.
      k_cache, v_cache: [n_layers, max_seq, n_heads, head_dim].
    """
    p = _unpack(cfg, params)
    s = cfg.max_seq
    x = p["tok_emb"][tokens] + p["pos_emb"]
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        xn = _rmsnorm(x, p[f"l{i}.ln1"])
        q = blocked_matmul(xn, p[f"l{i}.wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
        k = blocked_matmul(xn, p[f"l{i}.wk"]).reshape(s, cfg.n_heads, cfg.head_dim)
        v = blocked_matmul(xn, p[f"l{i}.wv"]).reshape(s, cfg.n_heads, cfg.head_dim)
        k_caches.append(k)
        v_caches.append(v)
        # [S,H,D] -> [H,S,D] for the kernel.
        o = flash_attention(
            q.transpose(1, 0, 2),
            k.transpose(1, 0, 2),
            v.transpose(1, 0, 2),
            length=length,
            causal=True,
        ).transpose(1, 0, 2)
        x = x + blocked_matmul(o.reshape(s, cfg.d_model), p[f"l{i}.wo"])
        xn2 = _rmsnorm(x, p[f"l{i}.ln2"])
        x = x + _ffn_prefill(xn2, p[f"l{i}.w_up"], p[f"l{i}.w_down"])
    x = _rmsnorm(x, p["ln_f"])
    last = x[length - 1]
    logits = last @ p["unembed"]
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(cfg: TransformerConfig, params: list[jax.Array], token: jax.Array,
                pos: jax.Array, k_cache: jax.Array, v_cache: jax.Array):
    """Generate logits for one new token at position `pos`.

    Args:
      token: scalar int32 (the previously emitted token).
      pos: scalar int32 position this token occupies.
      k_cache, v_cache: [n_layers, max_seq, n_heads, head_dim].

    Returns:
      (logits[vocab], k_cache, v_cache) with caches updated at `pos`.
    """
    p = _unpack(cfg, params)
    x = p["tok_emb"][token] + p["pos_emb"][pos]
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        xn = _rmsnorm(x, p[f"l{i}.ln1"])
        q = (xn @ p[f"l{i}.wq"]).reshape(cfg.n_heads, cfg.head_dim)
        k = (xn @ p[f"l{i}.wk"]).reshape(cfg.n_heads, cfg.head_dim)
        v = (xn @ p[f"l{i}.wv"]).reshape(cfg.n_heads, cfg.head_dim)
        kc = jax.lax.dynamic_update_index_in_dim(k_cache[i], k, pos, axis=0)
        vc = jax.lax.dynamic_update_index_in_dim(v_cache[i], v, pos, axis=0)
        new_k.append(kc)
        new_v.append(vc)
        o = kref.decode_attention_ref(q, kc, vc, pos)
        x = x + o.reshape(cfg.d_model) @ p[f"l{i}.wo"]
        xn2 = _rmsnorm(x, p[f"l{i}.ln2"])
        h = jax.nn.gelu(xn2 @ p[f"l{i}.w_up"])
        x = x + h @ p[f"l{i}.w_down"]
    x = _rmsnorm(x, p["ln_f"])
    logits = x @ p["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill_fn(cfg: TransformerConfig):
    """Positional-args prefill callable for AOT lowering."""
    n_params = len(param_spec(cfg))

    def fn(*args):
        params = list(args[:n_params])
        tokens, length = args[n_params], args[n_params + 1]
        return prefill(cfg, params, tokens, length)

    return fn


def decode_fn(cfg: TransformerConfig):
    """Positional-args decode callable for AOT lowering."""
    n_params = len(param_spec(cfg))

    def fn(*args):
        params = list(args[:n_params])
        token, pos, k_cache, v_cache = args[n_params : n_params + 4]
        return decode_step(cfg, params, token, pos, k_cache, v_cache)

    return fn


def reference_generate(
    cfg: TransformerConfig, params: list[jax.Array], prompt: list[int], n_new: int
) -> list[int]:
    """Greedy generation oracle used by tests (prefill + decode loop)."""
    s = cfg.max_seq
    assert len(prompt) + n_new <= s
    tokens = jnp.zeros((s,), jnp.int32).at[: len(prompt)].set(jnp.array(prompt))
    length = jnp.array(len(prompt), jnp.int32)
    logits, kc, vc = prefill(cfg, params, tokens, length)
    out = []
    tok = int(jnp.argmax(logits))
    out.append(tok)
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, kc, vc = decode_step(
            cfg, params, jnp.array(tok, jnp.int32), jnp.array(pos, jnp.int32), kc, vc
        )
        tok = int(jnp.argmax(logits))
        out.append(tok)
        pos += 1
    return out
