//! End-to-end driver (the repo's required full-stack proof): serve a
//! batched streaming workload where the DEVICE endpoint is a REAL
//! transformer executed through all three layers —
//!
//!   L1 Pallas flash-attention/matmul kernels (interpret-lowered)
//!     → L2 JAX transformer prefill/decode, AOT-lowered to HLO text
//!       → L3 Rust coordinator executing via the PJRT CPU client
//!
//! — racing an emulated commercial server endpoint under the DiSCo
//! dispatch policy, with latency/throughput reported at the end.
//!
//!   make artifacts && cargo run --release --example serve_live
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use disco::coordinator::policy::{Policy, PolicyKind};
use disco::profiles::ServerProfile;
use disco::runtime::{Manifest, ModelRunner};
use disco::serve::{LiveConfig, LiveRequest, LiveServer};
use disco::stats::describe::Summary;

fn main() -> anyhow::Result<()> {
    disco::util::logging::init();
    let dir = disco::runtime::artifacts_dir();
    let manifest = Manifest::load(&dir)
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;

    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let runner = ModelRunner::load(&client, manifest.variant("device_sm")?)?;
    println!(
        "loaded device model '{}' ({} params) via PJRT {}",
        runner.manifest.name,
        runner.manifest.param_count,
        client.platform_name()
    );

    // Server latencies scaled 0.2× so the demo finishes quickly; device
    // compute is REAL wall-clock PJRT execution.
    let server = LiveServer::new(
        runner,
        ServerProfile::gpt4o_mini(),
        LiveConfig {
            server_time_scale: 0.2,
            consumption_rate: 5.0,
            seed: 7,
        },
    );

    let n_requests = 24;
    let max_new = 24;
    let reqs: Vec<LiveRequest> = (0..n_requests as u64)
        .map(|id| LiveRequest {
            id,
            prompt: server
                .runner
                .tokenizer
                .synthetic_prompt(8 + (id as u32 * 17) % 120, id),
            max_new,
        })
        .collect();

    // Race both endpoints on every request (device budget b = 1).
    let policy = Policy::simple(PolicyKind::StochD, 1.0, false);
    let t0 = std::time::Instant::now();
    let records = server.serve(&reqs, &policy);
    let wall = t0.elapsed().as_secs_f64();

    let ttfts: Vec<f64> = records.iter().map(|r| r.ttft).collect();
    let mut tbts: Vec<f64> = Vec::new();
    for r in &records {
        tbts.extend_from_slice(&r.tbts);
    }
    let ttft = Summary::of(&ttfts);
    let tbt = Summary::of(&tbts);
    let total_tokens: usize = records.iter().map(|r| r.tokens.len()).sum();
    let device_wins = records
        .iter()
        .filter(|r| r.winner == disco::endpoint::EndpointKind::Device)
        .count();

    println!("\n=== end-to-end serving report ===");
    println!("requests        : {n_requests} (max_new = {max_new})");
    println!("wall time       : {wall:.2} s");
    println!("throughput      : {:.1} tokens/s end-to-end", total_tokens as f64 / wall);
    println!("TTFT            : mean {:.3} s, p99 {:.3} s", ttft.mean, ttft.p99);
    println!("perceived TBT   : mean {:.3} s, p99 {:.3} s", tbt.mean, tbt.p99);
    println!("prefill winners : device {device_wins} / server {}", records.len() - device_wins);
    println!("\nsample streams (device text is real greedy model output):");
    for r in records.iter().take(4) {
        println!(
            "  req {:>2} [{}]: ttft {:.3}s, {:?}",
            r.id,
            r.winner,
            r.ttft,
            r.text.chars().take(32).collect::<String>()
        );
    }
    anyhow::ensure!(total_tokens > 0, "no tokens generated");
    Ok(())
}
