//! Server-degradation failover (§2.3's motivating scenario): during a
//! load event, 30% of server requests hit a 20× TTFT spike. DiSCo-D's
//! Phase-1 tail protection (w_tail = F⁻¹(1−α)) starts the device before
//! the spike can hurt, bounding worst-case TTFT near the device's own
//! prefill time — while a server-only deployment's P99 explodes.
//!
//!   cargo run --release --example outage_failover

use disco::coordinator::policy::{Policy, PolicyKind};
use disco::cost::unified::Constraint;
use disco::profiles::{DeviceProfile, ServerProfile};
use disco::sim::engine::{Scenario, SimConfig};
use disco::trace::generator::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let device = DeviceProfile::xiaomi14_qwen0b5();
    let trace = WorkloadSpec::alpaca(1000).generate(7);

    println!(
        "{:<28} {:>12} {:>12} {:>12} {:>14}",
        "scenario", "mean TTFT", "p99 TTFT", "max TTFT", "device prefill%"
    );
    for (label, spike_prob, spike_scale) in [
        ("healthy server", 0.04, 4.0),
        ("degraded (30% × 20x)", 0.30, 20.0),
    ] {
        let mut profile = ServerProfile::gpt4o_mini();
        profile.spike_prob = spike_prob;
        profile.spike_scale = spike_scale;
        let scenario = Scenario::new(
            profile,
            device.clone(),
            Constraint::Device,
            SimConfig::default(),
        );
        let ecdf = scenario.profile_server_ttft(3000, 7);
        let disco = Policy::plan(PolicyKind::DiscoD, 0.5, false, &ecdf, &trace.prompt_lens());
        let server_only = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        for (name, policy) in [("  vLLM (server-only)", &server_only), ("  DiSCo-D b=0.5", &disco)]
        {
            let r = scenario.run_report(&trace, policy);
            println!(
                "{:<28} {:>11.3}s {:>11.3}s {:>11.3}s {:>13.1}%",
                format!("{label}{name}"),
                r.ttft.mean,
                r.ttft.p99,
                r.ttft.max,
                r.constrained_prefill_fraction.unwrap_or(1.0) * 100.0
            );
        }
    }
    println!(
        "\nDiSCo-D's wait-time strategy bounds the tail at F⁻¹(1−α) + device prefill —\n\
         the dispatcher needs no outage detection: the same profiled plan covers it."
    );
    Ok(())
}
