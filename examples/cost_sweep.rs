//! Budget-ratio sweep (Fig 6/7 style): how TTFT and cost move with b,
//! for DiSCo vs the stochastic baseline, under both constraint regimes.
//!
//!   cargo run --release --example cost_sweep [-- --requests 500]

use disco::cost::unified::Constraint;
use disco::experiments::common::*;
use disco::profiles::{DeviceProfile, ServerProfile};
use disco::sim::engine::{Scenario, SimConfig};
use disco::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("requests", 500)?;
    let seeds = args.get_u64("seeds", 3)?;
    let service = ServerProfile::deepseek_v25();
    let device = DeviceProfile::pixel7pro_bloom1b1();

    for constraint in [Constraint::Server, Constraint::Device] {
        let scenario = Scenario::new(
            service.clone(),
            device.clone(),
            constraint,
            SimConfig::default(),
        );
        println!(
            "\n=== {} × {} — {}-constrained ===",
            service.name,
            device.name,
            constraint_name(constraint)
        );
        println!(
            "{:>4} {:>14} {:>14} {:>16} {:>16}",
            "b", "DiSCo mean", "Stoch mean", "DiSCo cost ($)", "w/o migration ($)"
        );
        for &b in &BUDGET_GRID {
            let disco = run_cell(
                &service, &device, constraint, disco_for(constraint), b, true, n, seeds,
            );
            let stoch = run_cell(
                &service, &device, constraint, stoch_for(constraint), b, false, n, seeds,
            );
            let nomig = run_cell(
                &service, &device, constraint, disco_for(constraint), b, false, n, seeds,
            );
            println!(
                "{:>4.1} {:>13.3}s {:>13.3}s {:>16.6} {:>16.6}",
                b,
                avg_mean_ttft(&disco),
                avg_mean_ttft(&stoch),
                avg_cost(&disco, &scenario.costs),
                avg_cost(&nomig, &scenario.costs),
            );
        }
    }
    Ok(())
}
