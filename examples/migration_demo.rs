//! Token-level migration walkthrough (§4.3, Fig 4): shows the buffer
//! math (Eq. 5), the cost trigger (Eq. 4), and the before/after QoE and
//! cost of enabling migration on a device-constrained workload.
//!
//!   cargo run --release --example migration_demo

use disco::coordinator::migration::{MigrationConfig, MigrationPlanner};
use disco::cost::unified::{Constraint, CostParams};
use disco::endpoint::EndpointKind;
use disco::experiments::migration_exp::demo_migration_timeline;

fn main() -> anyhow::Result<()> {
    // --- the controller's arithmetic on one concrete handoff ----------
    let costs = CostParams {
        server_prefill: 1.4e-7, // DeepSeek input $/token
        server_decode: 2.8e-7,
        device_prefill: 4.3e-6, // Bloom-1.1B FLOPs × λ=5 $/PFLOP
        device_decode: 4.1e-6,
    };
    let planner = MigrationPlanner::new(MigrationConfig::default(), costs);
    println!("constraint classified as {:?}", costs.constraint());

    let remaining = 100u32; // tokens left to decode
    let reprefill = 48u32; // prompt + generated prefix
    let target_ttft = 1.3f64; // server re-prefill estimate (s)
    let plan = planner
        .plan(
            Constraint::Device,
            EndpointKind::Device,
            remaining,
            reprefill,
            target_ttft,
        )
        .expect("Eq. 4 favors migration here");
    println!("\nEq. 4 trigger:");
    println!(
        "  savings   = Δc_decode × remaining = {:.2e} × {remaining} = ${:.2e}",
        costs.decode_delta(),
        costs.decode_delta() * remaining as f64
    );
    println!(
        "  overhead  = c_s^p × reprefill    = {:.2e} × {reprefill} = ${:.2e}",
        costs.server_prefill,
        costs.server_prefill * reprefill as f64
    );
    println!("\nEq. 5 buffer:");
    println!(
        "  t_m = {:.2}s, r_c = {} tok/s  →  B = {} tokens buffered before handoff",
        plan.t_m_est, planner.config.consumption_rate, plan.buffer_tokens
    );
    println!("  target endpoint: {:?}", plan.target);

    // --- whole-workload effect ----------------------------------------
    let (with, without) = demo_migration_timeline(11);
    println!("\n=== 200-request DeepSeek × Pixel7Pro (device-constrained, b=0.6) ===");
    println!(
        "{:<22} {:>12} {:>12} {:>14} {:>12}",
        "", "TTFT p99", "TBT p99", "device decode", "migrated"
    );
    println!(
        "{:<22} {:>11.3}s {:>11.3}s {:>14} {:>12}",
        "DiSCo-D w/ migration",
        with.ttft.p99,
        with.tbt.p99,
        with.cost.device_decode_tokens,
        with.migrated_requests
    );
    println!(
        "{:<22} {:>11.3}s {:>11.3}s {:>14} {:>12}",
        "DiSCo-D w/o migration",
        without.ttft.p99,
        without.tbt.p99,
        without.cost.device_decode_tokens,
        without.migrated_requests
    );
    println!(
        "\nmigration moved {} decode tokens off the battery while delaying only {:.1} tokens/request (p99 {:.0})",
        without.cost.device_decode_tokens - with.cost.device_decode_tokens,
        with.delay_num_mean,
        with.delay_num_p99,
    );
    Ok(())
}
