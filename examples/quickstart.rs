//! Quickstart: the minimal DiSCo workflow in ~40 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Plans a server-constrained DiSCo policy from profiled distributions,
//! replays an Alpaca-like trace against GPT-4o-mini × Pixel 7 Pro, and
//! compares QoE against the stochastic baseline at the same budget.

use disco::coordinator::policy::{Policy, PolicyKind};
use disco::cost::unified::Constraint;
use disco::profiles::{DeviceProfile, ServerProfile};
use disco::sim::engine::{Scenario, SimConfig};
use disco::trace::generator::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    // 1. Pick a scenario: commercial service + on-device configuration.
    let scenario = Scenario::new(
        ServerProfile::gpt4o_mini(),
        DeviceProfile::pixel7pro_bloom1b1(),
        Constraint::Server, // API dollars are the scarce resource
        SimConfig::default(),
    );

    // 2. A 1,000-request workload (Alpaca lengths, Poisson arrivals).
    let trace = WorkloadSpec::alpaca(1000).generate(42);

    // 3. Plan DiSCo-S at budget b = 0.5: the dispatcher profiles the
    //    server TTFT distribution and the prompt-length distribution,
    //    then solves Eq. 3 for the device/server length threshold.
    let b = 0.5;
    let server_ttft = scenario.profile_server_ttft(2000, 42);
    let disco = Policy::plan(PolicyKind::DiscoS, b, true, &server_ttft, &trace.prompt_lens());
    let stoch = Policy::simple(PolicyKind::StochS, b, false);

    // 4. Run both and compare.
    let r_disco = scenario.run_report(&trace, &disco);
    let r_stoch = scenario.run_report(&trace, &stoch);

    println!("GPT-4o-mini × Pixel7Pro/Bloom-1.1B, server budget b = {b}");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>10}",
        "policy", "mean TTFT", "p99 TTFT", "TBT p99", "migrated"
    );
    for (name, r) in [("DiSCo-S", &r_disco), ("Stoch-S", &r_stoch)] {
        println!(
            "{:<10} {:>11.3}s {:>11.3}s {:>11.3}s {:>10}",
            name, r.ttft.mean, r.ttft.p99, r.tbt.p99, r.migrated_requests
        );
    }
    println!(
        "\nDiSCo cuts mean TTFT by {:.1}% and tail TTFT by {:.1}% at the same budget",
        (r_stoch.ttft.mean - r_disco.ttft.mean) / r_stoch.ttft.mean * 100.0,
        (r_stoch.ttft.p99 - r_disco.ttft.p99) / r_stoch.ttft.p99 * 100.0,
    );
    // Budget compliance: both spend ≤ b of prompt tokens on the server.
    println!(
        "server prefill fraction: DiSCo {:.3}, Stoch {:.3} (budget {b})",
        r_disco.constrained_prefill_fraction.unwrap(),
        r_stoch.constrained_prefill_fraction.unwrap()
    );
    Ok(())
}
