//! Offline shim for the `log` facade crate: the [`Log`] trait, level
//! types, global logger registration, and the five logging macros — just
//! enough for the workspace's logger backend in `disco::util::logging`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record (higher = chattier).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Global maximum verbosity filter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record.
#[derive(Clone, Copy, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: metadata plus pre-formatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

/// Returned when a second logger is registered.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already registered")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until init

/// Register the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the registered logger.
#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record {
                metadata: Metadata { level },
                args,
            };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__private_log($crate::Level::Trace, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_compare_to_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(!(Level::Debug <= LevelFilter::Info));
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrips() {
        set_max_level(LevelFilter::Warn);
        assert_eq!(max_level(), LevelFilter::Warn);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_are_callable_without_a_logger() {
        info!("no logger registered: {}", 1);
        error!("still fine");
    }
}
