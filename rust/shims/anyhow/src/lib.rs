//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this reimplements the
//! small slice of `anyhow` the workspace uses: [`Error`], [`Result`], and
//! the [`anyhow!`], [`bail!`], [`ensure!`] macros. Like the real crate,
//! [`Error`] deliberately does **not** implement `std::error::Error`, so
//! the blanket `From<E: Error>` conversion (what makes `?` work) does not
//! conflict with the identity `From` impl.

use std::fmt;

/// A type-erased error with a display message.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>`, with an overridable error type like the
/// real crate's alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Wrap any displayable message into an error.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        fn inner(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert_eq!(inner(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(inner(12).unwrap_err().to_string(), "n too big: 12");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
        assert_eq!(format!("{e:?}"), "code 7");
    }
}
