//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate links against a vendored `xla_extension` build that is
//! not present in this environment. This stub keeps the `runtime`/`serve`
//! layers compiling so the simulator, experiments, and tests build and run
//! everywhere; any attempt to actually create a PJRT client reports a
//! clear "runtime unavailable" error instead. Swap the `xla` entry in
//! `rust/Cargo.toml` back to the real bindings to run the live path.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real crate's (Debug-formatted at call sites).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built with the offline `xla` stub; \
         link the real xla bindings to enable the live serving path)"
    ))
}

/// Element dtypes (only what the workspace constructs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side literal value (stub: carries no data).
#[derive(Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn scalar(_value: i32) -> Literal {
        Literal
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple3"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready to compile (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literals_construct_but_do_not_read() {
        let l = Literal::vec1(&[1, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::scalar(4).to_tuple3().is_err());
    }
}
