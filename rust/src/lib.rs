//! # DiSCo — Device-Server Cooperative LLM text streaming
//!
//! Reproduction of *"DiSCo: Device-Server Collaborative LLM-based Text
//! Streaming Services"* (ACL 2025 Findings) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate is organized bottom-up:
//!
//! - [`util`] — zero-dependency substrates (RNG, JSON, CSV, CLI, logging)
//! - [`stats`] — distributions, descriptive statistics, ECDF, fitting
//! - [`cost`] — unified cost model: FLOPs energy + API pricing + λ
//! - [`profiles`] — calibrated service (server) and device models
//! - [`trace`] — workload/trace generation and IO
//! - [`endpoint`] — simulated + real (PJRT) inference endpoints
//! - [`coordinator`] — the paper's contribution: dispatch + migration
//! - [`sim`] — deterministic discrete-event simulation engine
//! - [`metrics`] — QoE accounting (TTFT/TBT/delay_num/cost)
//! - [`predictor`] — TTFT predictors (Appendix C)
//! - [`quality`] — migration quality bounds (Appendix D)
//! - [`runtime`] — PJRT bridge: load AOT HLO artifacts, run the model
//! - [`serve`] — live thread-based serving loop over real endpoints
//! - [`experiments`] — regenerate every table/figure of the paper
//! - [`benchlib`] / [`proptest`] — in-repo micro-bench & property-test
//!   harnesses (criterion/proptest are unavailable offline)

pub mod benchlib;
pub mod coordinator;
pub mod cost;
pub mod endpoint;
pub mod experiments;
pub mod metrics;
pub mod predictor;
pub mod profiles;
pub mod proptest;
pub mod quality;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
