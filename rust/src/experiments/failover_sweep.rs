//! Failover sweep: migration storms under shard failure.
//!
//! Each cell kills one shard of a K-shard fleet mid-burst (a scheduled
//! [`crate::sim::fleet::ShardOutage`]) and replays the same
//! device-constrained workload
//! under a (migration policy × balancer × outage timing) grid. The
//! migration-policy axis is the PR's headline comparison: §4.3 disabled,
//! §4.3 with the legacy base-endpoint re-prefill target, and §4.3 with
//! shard-targeted re-prefill ([`MigrationTargeting::ShardTargeted`] —
//! least-work-with-estimate, the mode that also spreads the dead shard's
//! re-queued streams across the survivors instead of piling them onto a
//! single replacement). Cells at the same seed replay the identical
//! trace and latency draws, so TTFT differences are pure
//! targeting/failover effects. Cells fan out via
//! [`crate::experiments::common::par_map`] with [`CellSeed`]
//! content-derived seeding.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::{FleetConfig, MigrationTargeting};
use crate::trace::generator::{Arrival, WorkloadSpec};
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// Migration-policy axis of the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationAxis {
    /// §4.3 disabled entirely (the no-migration baseline).
    Off,
    /// Migration on, legacy base-endpoint re-prefill target.
    Legacy,
    /// Migration on, shard-targeted re-prefill (least-work-with-estimate).
    ShardTargeted,
}

impl MigrationAxis {
    /// All axes, in report order.
    pub fn all() -> Vec<MigrationAxis> {
        vec![
            MigrationAxis::Off,
            MigrationAxis::Legacy,
            MigrationAxis::ShardTargeted,
        ]
    }

    /// Short label used in tables and CSVs.
    pub fn label(&self) -> &'static str {
        match self {
            MigrationAxis::Off => "off",
            MigrationAxis::Legacy => "legacy",
            MigrationAxis::ShardTargeted => "shard-targeted",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<MigrationAxis> {
        Some(match s.to_ascii_lowercase().as_str() {
            "off" | "none" => MigrationAxis::Off,
            "legacy" | "base" | "base-endpoint" => MigrationAxis::Legacy,
            "shard" | "targeted" | "shard-targeted" => MigrationAxis::ShardTargeted,
            _ => return None,
        })
    }

    /// Whether the §4.3 controller runs.
    pub fn migration_enabled(&self) -> bool {
        !matches!(self, MigrationAxis::Off)
    }

    /// The fleet-side targeting mode this axis runs.
    pub fn targeting(&self) -> MigrationTargeting {
        match self {
            MigrationAxis::ShardTargeted => MigrationTargeting::ShardTargeted,
            _ => MigrationTargeting::BaseEndpoint,
        }
    }
}

/// One cell of the failover-sweep grid.
#[derive(Clone, Copy, Debug)]
pub struct FailoverCell {
    pub axis: MigrationAxis,
    pub balancer: BalancerKind,
    /// When the shard dies, as a fraction of the trace's arrival span.
    pub outage_frac: f64,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct FailoverCellResult {
    pub cell: FailoverCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub p99_queue_delay: f64,
    /// Migrated requests per run.
    pub migrated: f64,
    /// §4.3 re-prefills routed onto a concrete shard.
    pub migration_targeted: f64,
    /// Shard-targeted migrations that found no admitting shard.
    pub migration_fallbacks: f64,
    /// Queued streams re-routed off the dead shard.
    pub outage_requeues: f64,
}

/// Sweep parameters, shared by the `failover-sweep` experiment and the
/// `failover_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct FailoverSweepParams {
    pub axes: Vec<MigrationAxis>,
    pub balancers: Vec<BalancerKind>,
    pub outage_fracs: Vec<f64>,
    pub shards: usize,
    pub slots_per_shard: usize,
    /// Which shard the outage kills.
    pub outage_shard: usize,
    /// Burst arrival rate (req/s) — size it past one shard's capacity so
    /// the dead shard has a queue worth re-routing.
    pub rate_rps: f64,
    /// Gamma arrival cv (> 1 = burstier than Poisson).
    pub burst_cv: f64,
    /// Dispatch policy every cell runs (a device-constrained racer, so
    /// device-won streams migrate onto the shard fleet).
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for FailoverSweepParams {
    fn default() -> Self {
        FailoverSweepParams {
            axes: MigrationAxis::all(),
            balancers: vec![BalancerKind::RoundRobin, BalancerKind::LeastWork],
            outage_fracs: vec![0.25, 0.5, 0.75],
            shards: 4,
            slots_per_shard: 1,
            outage_shard: 0,
            // DeepSeek service ≈ 1.3 s ⇒ ~0.75 rps per slot; 4 rps over
            // a K=4/1-slot fleet is a sustained ~1.3× overload.
            rate_rps: 4.0,
            burst_cv: 2.0,
            policy: PolicyKind::StochD,
            b: 1.0,
            n_requests: 300,
            n_seeds: 3,
            service: ServerProfile::deepseek_v25(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

impl FailoverSweepParams {
    /// Number of grid cells.
    pub fn n_cells(&self) -> usize {
        self.axes.len() * self.balancers.len() * self.outage_fracs.len()
    }
}

/// Run the (axis × balancer × outage-time) grid in parallel; cells come
/// back in grid order (axes outer, balancers middle, outage times inner).
pub fn run_grid(params: &FailoverSweepParams) -> Vec<FailoverCellResult> {
    let mut cells = Vec::with_capacity(params.n_cells());
    for &axis in &params.axes {
        for &balancer in &params.balancers {
            for &outage_frac in &params.outage_fracs {
                cells.push(FailoverCell {
                    axis,
                    balancer,
                    outage_frac,
                });
            }
        }
    }
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &FailoverSweepParams, cell: &FailoverCell) -> FailoverCellResult {
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut qd_p99 = Vec::new();
    let mut migrated = Vec::new();
    let mut targeted = Vec::new();
    let mut fallbacks = Vec::new();
    let mut requeues = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seed over the arrival rate only: every axis,
        // balancer, and outage time at the same seed replays the
        // identical trace and latency draws (paired comparison).
        let cell_seed = CellSeed::new(seed).mix_f64(params.rate_rps);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Device,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Gamma {
                mean_gap: 1.0 / params.rate_rps,
                cv: params.burst_cv,
            },
            ..WorkloadSpec::alpaca(params.n_requests)
        };
        let trace = spec.generate(cell_seed.trace(0xFA110E4));
        let span = trace
            .requests
            .last()
            .map_or(0.0, |r| r.arrival - trace.requests[0].arrival);
        let fleet = FleetConfig::sharded(params.shards, params.slots_per_shard, cell.balancer)
            .with_migration_targeting(cell.axis.targeting())
            .with_outage(cell.outage_frac * span, params.outage_shard);
        let policy = make_policy(
            params.policy,
            params.b,
            cell.axis.migration_enabled(),
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let rep = scenario.run_fleet_report(&trace, &policy, &fleet);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        qd_p99.push(rep.load.server_queue_delay.p99);
        migrated.push(rep.qoe.migrated_requests as f64);
        targeted.push(rep.load.migration_targeted as f64);
        fallbacks.push(rep.load.migration_fallbacks as f64);
        requeues.push(rep.load.outage_requeues as f64);
    }
    let avg = crate::stats::describe::mean;
    FailoverCellResult {
        cell: *cell,
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        p99_queue_delay: avg(&qd_p99),
        migrated: avg(&migrated),
        migration_targeted: avg(&targeted),
        migration_fallbacks: avg(&fallbacks),
        outage_requeues: avg(&requeues),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[FailoverCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.cell.axis.label().to_string(),
                r.cell.balancer.label().to_string(),
                format!("{:.2}", r.cell.outage_frac),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.p99_queue_delay),
                format!("{:.1}", r.migrated),
                format!("{:.1}", r.migration_targeted),
                format!("{:.1}", r.migration_fallbacks),
                format!("{:.1}", r.outage_requeues),
            ]
        })
        .collect();
    render_table(
        &[
            "migration",
            "balancer",
            "outage@",
            "mean TTFT",
            "p99 TTFT",
            "p99 queue",
            "migrated",
            "targeted",
            "fallbacks",
            "requeues",
        ],
        &rows,
    )
}

/// The `failover-sweep` experiment entry: default grid, CSV + table.
pub fn failover_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = FailoverSweepParams {
        n_requests: ctx.n_requests.clamp(50, 300),
        n_seeds: ctx.n_seeds.clamp(1, 3),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "migration",
        "balancer",
        "outage_frac",
        "mean_ttft",
        "p99_ttft",
        "p99_queue_delay",
        "migrated",
        "migration_targeted",
        "migration_fallbacks",
        "outage_requeues",
    ]);
    for r in &results {
        csv.rowd(&[
            r.cell.axis.label().to_string(),
            r.cell.balancer.label().to_string(),
            format!("{:.3}", r.cell.outage_frac),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.p99_queue_delay),
            format!("{:.2}", r.migrated),
            format!("{:.2}", r.migration_targeted),
            format!("{:.2}", r.migration_fallbacks),
            format!("{:.2}", r.outage_requeues),
        ]);
    }
    csv.write(&ctx.csv_path("failover-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> FailoverSweepParams {
        FailoverSweepParams {
            axes: vec![MigrationAxis::Legacy, MigrationAxis::ShardTargeted],
            balancers: vec![BalancerKind::RoundRobin],
            outage_fracs: vec![0.5],
            n_requests: 80,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_axes_and_exercises_failover() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].cell.axis, MigrationAxis::Legacy);
        assert_eq!(results[1].cell.axis, MigrationAxis::ShardTargeted);
        for r in &results {
            assert!(r.mean_ttft > 0.0);
            assert!(r.migrated > 0.0, "{}: migration must fire", r.cell.axis.label());
        }
        // Only the shard-targeted axis books re-prefills onto shards.
        assert_eq!(results[0].migration_targeted, 0.0);
        assert!(results[1].migration_targeted > 0.0);
    }

    #[test]
    fn migration_axis_parse_roundtrips() {
        for a in MigrationAxis::all() {
            assert_eq!(MigrationAxis::parse(a.label()), Some(a));
        }
        assert_eq!(MigrationAxis::parse("base"), Some(MigrationAxis::Legacy));
        assert_eq!(
            MigrationAxis::parse("shard"),
            Some(MigrationAxis::ShardTargeted)
        );
        assert!(MigrationAxis::parse("nope").is_none());
        assert!(!MigrationAxis::Off.migration_enabled());
        assert_eq!(
            MigrationAxis::ShardTargeted.targeting(),
            MigrationTargeting::ShardTargeted
        );
    }

    #[test]
    fn failover_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_failover_sweep"),
            n_seeds: 1,
            n_requests: 60,
        };
        let out = failover_sweep(&ctx).unwrap();
        assert!(out.contains("migration"));
        let csv = std::fs::read_to_string(ctx.csv_path("failover-sweep")).unwrap();
        // Header + 3 axes × 2 balancers × 3 outage times.
        assert_eq!(csv.lines().count(), 1 + 18);
        assert_eq!(FailoverSweepParams::default().n_cells(), 18);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
