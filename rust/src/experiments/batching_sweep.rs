//! Batching sweep: continuous batching vs the slot model across the
//! (prefill-token budget × arrival rate × batch latency curve) grid.
//!
//! Each cell runs the same workload twice on a K-shard fleet: once
//! under [`BatchingMode::Continuous`] with the cell's token budget and
//! latency curve, and once under the equivalent slot-legacy topology
//! (`slots_per_shard` admissions per shard) — the PR-4 model the
//! tentpole replaces. Cells at the same (rate, seed) replay the
//! identical trace and latency draws, so the TTFT gap between the two
//! columns is a pure admission-model effect: the slot model holds a
//! slot through decode and queues admissions behind it, while the token
//! gate admits prefills against the budget and lets decode share the
//! batch (paying the curve's slowdown in TBT instead). Cells fan out
//! via [`crate::experiments::common::par_map`] with [`CellSeed`]
//! content-derived seeding.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::batching::{BatchLatencyCurve, BatchingMode, ContinuousBatchConfig, PricingMode};
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the batching-sweep grid.
#[derive(Clone, Copy, Debug)]
pub struct BatchingCell {
    /// Prompt tokens admitted per scheduling tick per shard.
    pub budget: u32,
    pub rate_rps: f64,
    pub curve: BatchLatencyCurve,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct BatchingCellResult {
    pub cell: BatchingCell,
    /// Continuous-batching QoE (join-time pricing).
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tbt: f64,
    pub p99_tbt: f64,
    /// The same trace re-run under iteration-level repricing
    /// ([`PricingMode::IterationLevel`]) — the paired column that shows
    /// what the join-time approximation hides. Flat-curve cells are
    /// byte-identical across the pair.
    pub repriced_mean_tbt: f64,
    pub repriced_p99_tbt: f64,
    /// Seed-averaged batch-composition repricing passes in the repriced
    /// run (zero in Flat cells, where slowdowns never change).
    pub reprice_events: f64,
    /// Largest batch size any shard reached.
    pub peak_batch: f64,
    /// Admitted prompt tokens over the budget made available.
    pub token_utilization: f64,
    /// The slot-legacy baseline's p99 TTFT on the identical trace.
    pub slot_p99_ttft: f64,
}

/// Sweep parameters, shared by the `batching-sweep` experiment and the
/// `batching_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct BatchingSweepParams {
    pub budgets: Vec<u32>,
    pub rates: Vec<f64>,
    pub curves: Vec<BatchLatencyCurve>,
    /// Seconds between admission ticks.
    pub tick_interval: f64,
    /// Optional per-shard cap on concurrently decoding streams.
    pub max_batch: Option<usize>,
    pub shards: usize,
    /// Admissions per shard for the slot-legacy baseline column.
    pub slots_per_shard: usize,
    pub balancer: BalancerKind,
    /// Dispatch policy every cell runs (ServerOnly isolates the
    /// admission model from device-race effects).
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for BatchingSweepParams {
    fn default() -> Self {
        BatchingSweepParams {
            budgets: vec![32, 64, 128],
            // Around and past the slot baseline's capacity (K=2 shards ×
            // 2 slots over a ~1.3 s mean stream ≈ 3 req/s).
            rates: vec![1.0, 3.0, 6.0],
            curves: vec![
                BatchLatencyCurve::Flat,
                BatchLatencyCurve::Knee {
                    knee: 8,
                    alpha: 0.05,
                },
                BatchLatencyCurve::Linear { alpha: 0.05 },
            ],
            tick_interval: 0.25,
            max_batch: None,
            shards: 2,
            slots_per_shard: 2,
            balancer: BalancerKind::JoinShortestQueue,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_requests: 300,
            n_seeds: 2,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

impl BatchingSweepParams {
    /// Number of grid cells.
    pub fn n_cells(&self) -> usize {
        self.budgets.len() * self.rates.len() * self.curves.len()
    }
}

/// The (scenario, trace, policy) triple a (rate, seed) pair replays —
/// shared by every budget/curve cell at that pair and by the slot
/// baseline, so comparisons are paired by construction.
fn cell_workload(
    params: &BatchingSweepParams,
    rate_rps: f64,
    seed: u64,
) -> (Scenario, crate::trace::Trace, crate::coordinator::policy::Policy) {
    // Content-derived seed over the arrival rate only.
    let cell_seed = CellSeed::new(seed).mix_f64(rate_rps);
    let scenario = Scenario::new(
        params.service.clone(),
        params.device.clone(),
        Constraint::Server,
        SimConfig {
            seed: cell_seed.scenario(),
            ..Default::default()
        },
    );
    let trace = WorkloadSpec::alpaca(params.n_requests)
        .at_rate(rate_rps)
        .generate(cell_seed.trace(0xBA7C4));
    let policy = make_policy(
        params.policy,
        params.b,
        false,
        &scenario,
        &trace,
        cell_seed.scenario(),
    );
    (scenario, trace, policy)
}

/// Seed-averaged slot-legacy p99 TTFT at one rate (the baseline column
/// depends only on the rate — budgets and curves don't touch it — so it
/// is simulated once per rate, not once per cell).
fn slot_baseline_p99(params: &BatchingSweepParams, rate_rps: f64) -> f64 {
    let slot = FleetConfig::sharded(params.shards, params.slots_per_shard, params.balancer);
    let mut p99 = Vec::new();
    for seed in 0..params.n_seeds {
        let (scenario, trace, policy) = cell_workload(params, rate_rps, seed);
        p99.push(scenario.run_fleet_report(&trace, &policy, &slot).qoe.ttft.p99);
    }
    crate::stats::describe::mean(&p99)
}

/// Run the (budget × rate × curve) grid in parallel; cells come back in
/// grid order (budgets outer, rates middle, curves inner).
pub fn run_grid(params: &BatchingSweepParams) -> Vec<BatchingCellResult> {
    let baselines: Vec<f64> =
        par_map(&params.rates, |_, &rate| slot_baseline_p99(params, rate));
    let mut cells = Vec::with_capacity(params.n_cells());
    for &budget in &params.budgets {
        for (ri, &rate_rps) in params.rates.iter().enumerate() {
            for &curve in &params.curves {
                cells.push((
                    BatchingCell {
                        budget,
                        rate_rps,
                        curve,
                    },
                    baselines[ri],
                ));
            }
        }
    }
    par_map(&cells, |_, pair| run_cell(params, &pair.0, pair.1))
}

fn run_cell(
    params: &BatchingSweepParams,
    cell: &BatchingCell,
    slot_p99_ttft: f64,
) -> BatchingCellResult {
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut mean_tbt = Vec::new();
    let mut p99_tbt = Vec::new();
    let mut rp_mean_tbt = Vec::new();
    let mut rp_p99_tbt = Vec::new();
    let mut rp_events = Vec::new();
    let mut peak = Vec::new();
    let mut token_util = Vec::new();
    for seed in 0..params.n_seeds {
        let (scenario, trace, policy) = cell_workload(params, cell.rate_rps, seed);
        let continuous =
            FleetConfig::sharded(params.shards, params.slots_per_shard, params.balancer)
                .with_batching(BatchingMode::Continuous(ContinuousBatchConfig {
                    prefill_tokens_per_tick: cell.budget,
                    tick_interval: params.tick_interval,
                    max_batch: params.max_batch,
                    curve: cell.curve,
                }));
        let cont_rep = scenario.run_fleet_report(&trace, &policy, &continuous);
        mean_ttft.push(cont_rep.qoe.ttft.mean);
        p99_ttft.push(cont_rep.qoe.ttft.p99);
        mean_tbt.push(cont_rep.qoe.tbt.mean);
        p99_tbt.push(cont_rep.qoe.tbt.p99);
        peak.push(cont_rep.load.peak_batch() as f64);
        token_util.push(cont_rep.load.token_budget_utilization().unwrap_or(0.0));
        // Paired repriced leg: identical trace, draws, and fleet — the
        // only difference is iteration-level vs join-time decode pricing.
        let repriced = scenario.run_fleet_report(
            &trace,
            &policy,
            &continuous.clone().with_pricing(PricingMode::IterationLevel),
        );
        rp_mean_tbt.push(repriced.qoe.tbt.mean);
        rp_p99_tbt.push(repriced.qoe.tbt.p99);
        rp_events.push(repriced.load.reprice_events as f64);
    }
    let avg = crate::stats::describe::mean;
    BatchingCellResult {
        cell: *cell,
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        mean_tbt: avg(&mean_tbt),
        p99_tbt: avg(&p99_tbt),
        repriced_mean_tbt: avg(&rp_mean_tbt),
        repriced_p99_tbt: avg(&rp_p99_tbt),
        reprice_events: avg(&rp_events),
        peak_batch: avg(&peak),
        token_utilization: avg(&token_util),
        slot_p99_ttft,
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[BatchingCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.cell.budget),
                format!("{:.2}", r.cell.rate_rps),
                r.cell.curve.label(),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.4}", r.mean_tbt),
                format!("{:.3}", r.p99_tbt),
                format!("{:.4}", r.repriced_mean_tbt),
                format!("{:.3}", r.repriced_p99_tbt),
                format!("{:.0}", r.reprice_events),
                format!("{:.1}", r.peak_batch),
                format!("{:.2}", r.token_utilization),
                format!("{:.3}", r.slot_p99_ttft),
            ]
        })
        .collect();
    render_table(
        &[
            "budget/tick",
            "rate (req/s)",
            "curve",
            "mean TTFT",
            "p99 TTFT",
            "mean TBT",
            "p99 TBT",
            "rp mean TBT",
            "rp p99 TBT",
            "reprices",
            "peak batch",
            "token util",
            "slot p99 TTFT",
        ],
        &rows,
    )
}

/// The `batching-sweep` experiment entry: default grid, CSV + table.
pub fn batching_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = BatchingSweepParams {
        n_requests: ctx.n_requests.clamp(50, 300),
        n_seeds: ctx.n_seeds.clamp(1, 2),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "budget_per_tick",
        "rate_rps",
        "curve",
        "mean_ttft",
        "p99_ttft",
        "mean_tbt",
        "p99_tbt",
        "repriced_mean_tbt",
        "repriced_p99_tbt",
        "reprice_events",
        "peak_batch",
        "token_utilization",
        "slot_p99_ttft",
    ]);
    for r in &results {
        csv.rowd(&[
            format!("{}", r.cell.budget),
            format!("{:.3}", r.cell.rate_rps),
            r.cell.curve.label(),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.mean_tbt),
            format!("{:.4}", r.p99_tbt),
            format!("{:.4}", r.repriced_mean_tbt),
            format!("{:.4}", r.repriced_p99_tbt),
            format!("{:.1}", r.reprice_events),
            format!("{:.2}", r.peak_batch),
            format!("{:.4}", r.token_utilization),
            format!("{:.4}", r.slot_p99_ttft),
        ]);
    }
    csv.write(&ctx.csv_path("batching-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> BatchingSweepParams {
        BatchingSweepParams {
            budgets: vec![64],
            rates: vec![1.0, 4.0],
            curves: vec![BatchLatencyCurve::Flat, BatchLatencyCurve::Linear { alpha: 0.1 }],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_axes_and_batches() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.cell.rate_rps, params.rates[(i / 2) % 2]);
            assert!(r.mean_ttft > 0.0);
            assert!(r.token_utilization >= 0.0);
            assert!(r.peak_batch >= 1.0, "streams must enter the batch");
            if matches!(r.cell.curve, BatchLatencyCurve::Flat) {
                // Flat cells: repricing is provably inert, so the paired
                // column is bit-identical to the join-time column.
                assert_eq!(r.repriced_mean_tbt, r.mean_tbt, "Flat repriced leg diverged");
                assert_eq!(r.repriced_p99_tbt, r.p99_tbt, "Flat repriced leg diverged");
                assert_eq!(r.reprice_events, 0.0, "Flat cells must never reprice");
            }
        }
        // The overloaded Linear cell churns batch composition, so the
        // repriced leg must actually re-stamp timelines.
        let hot_linear = &results[3];
        assert!(matches!(hot_linear.cell.curve, BatchLatencyCurve::Linear { .. }));
        assert!(
            hot_linear.reprice_events > 0.0,
            "overloaded Linear cell produced no reprice events"
        );
        // At the overloaded rate the slot baseline queues harder than
        // the token gate admits: continuous p99 must not meaningfully
        // exceed it on this short trace (the big-margin headline claim
        // lives in the integration acceptance test).
        let hot_flat = &results[2];
        assert_eq!(hot_flat.cell.rate_rps, 4.0);
        assert!(
            hot_flat.p99_ttft <= hot_flat.slot_p99_ttft * 1.25,
            "continuous p99 {:.2}s vs slot {:.2}s at overload",
            hot_flat.p99_ttft,
            hot_flat.slot_p99_ttft
        );
    }

    #[test]
    fn batching_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_batching_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = batching_sweep(&ctx).unwrap();
        assert!(out.contains("budget/tick"));
        let csv = std::fs::read_to_string(ctx.csv_path("batching-sweep")).unwrap();
        // Header + 3 budgets × 3 rates × 3 curves.
        assert_eq!(csv.lines().count(), 1 + 27);
        assert_eq!(BatchingSweepParams::default().n_cells(), 27);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
