//! §3 characterization experiments: Fig 2, Fig 3, Table 1.

use crate::endpoint::{DeviceEndpoint, ServerEndpoint, SimEndpoint};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::stats::corr::pearson;
use crate::stats::describe::Summary;
use crate::util::csv::CsvWriter;
use crate::util::render_table;
use crate::util::rng::Rng;

/// Fig 2: identical prompt fired at 60 s intervals — device TTFT is
/// stable, server TTFT spikes.
pub fn fig2(ctx: &ExpContext) -> anyhow::Result<String> {
    let n = 60usize;
    let prompt_len = 64u32;
    let mut csv = CsvWriter::new(&["setup", "sample_idx", "ttft_s"]);
    let mut rows = Vec::new();

    let servers = ServerProfile::all();
    let devices = [
        DeviceProfile::a40_qwen7b(),
        DeviceProfile::rtx3080x2_llama8b(),
    ];

    for p in &servers {
        let ep = ServerEndpoint::new(p.clone());
        let mut rng = Rng::new(2);
        let ttfts: Vec<f64> = (0..n).map(|_| ep.sample_ttft(prompt_len, &mut rng)).collect();
        for (i, t) in ttfts.iter().enumerate() {
            csv.rowd(&[format!("server/{}", p.name), i.to_string(), format!("{t:.4}")]);
        }
        let s = Summary::of(&ttfts);
        rows.push(vec![
            format!("server/{}", p.name),
            format!("{:.3}", s.mean),
            format!("{:.3}", s.std),
            format!("{:.2}", s.std / s.mean),
            format!("{:.3}", s.max),
        ]);
    }
    for p in &devices {
        let ep = DeviceEndpoint::new(p.clone());
        let mut rng = Rng::new(3);
        let ttfts: Vec<f64> = (0..n).map(|_| ep.sample_ttft(prompt_len, &mut rng)).collect();
        for (i, t) in ttfts.iter().enumerate() {
            csv.rowd(&[format!("device/{}", p.name), i.to_string(), format!("{t:.4}")]);
        }
        let s = Summary::of(&ttfts);
        rows.push(vec![
            format!("device/{}", p.name),
            format!("{:.3}", s.mean),
            format!("{:.3}", s.std),
            format!("{:.2}", s.std / s.mean),
            format!("{:.3}", s.max),
        ]);
    }
    csv.write(&ctx.csv_path("fig2"))?;
    Ok(render_table(
        &["setup", "mean_ttft", "std", "cv", "max"],
        &rows,
    ))
}

/// Table 1: Pearson coefficient between prompt length and TTFT.
pub fn table1(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["model", "deployment", "pearson", "paper_value"]);
    let mut rows = Vec::new();
    let paper: &[(&str, f64)] = &[
        ("Command", 0.0142),
        ("GPT", 0.0236),
        ("DeepSeek", -0.0273),
        ("LLaMA", 0.0402),
    ];
    let mut rng = Rng::new(11);
    let lens: Vec<u32> = (0..ctx.n_requests)
        .map(|_| (rng.lognormal(3.0, 0.9).round() as u32).clamp(4, 1024))
        .collect();
    let xs: Vec<f64> = lens.iter().map(|&l| l as f64).collect();

    for p in ServerProfile::all() {
        let ep = ServerEndpoint::new(p.clone());
        let ys: Vec<f64> = lens.iter().map(|&l| ep.sample_ttft(l, &mut rng)).collect();
        let r = pearson(&xs, &ys);
        let paper_v = paper
            .iter()
            .find(|(n, _)| *n == p.name)
            .map(|(_, v)| *v)
            .unwrap_or(0.0);
        csv.rowd(&[
            p.name.to_string(),
            "Server".into(),
            format!("{r:.4}"),
            format!("{paper_v:.4}"),
        ]);
        rows.push(vec![
            p.name.to_string(),
            "Server".into(),
            format!("{r:.4}"),
            format!("{paper_v:.4}"),
        ]);
    }
    let dev = DeviceEndpoint::new(DeviceProfile::rtx3080x2_llama8b());
    let ys: Vec<f64> = lens.iter().map(|&l| dev.sample_ttft(l, &mut rng)).collect();
    let r = pearson(&xs, &ys);
    csv.rowd(&[
        "LLaMA-3.1-8b".into(),
        "Device".into(),
        format!("{r:.4}"),
        "0.8424".to_string(),
    ]);
    rows.push(vec![
        "LLaMA-3.1-8b".into(),
        "Device".into(),
        format!("{r:.4}"),
        "0.8424".into(),
    ]);
    csv.write(&ctx.csv_path("table1"))?;
    Ok(render_table(
        &["model", "deployment", "pearson", "paper"],
        &rows,
    ))
}

/// Fig 3: TBT distributions — device steady, server packetized/variable.
pub fn fig3(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["setup", "mean_tbt", "p50", "p99", "zero_frac"]);
    let mut rows = Vec::new();
    let mut rng = Rng::new(21);
    let n_tokens = 20_000u32;

    let mut push = |name: String, gaps: Vec<f64>| {
        let zero = gaps.iter().filter(|g| **g == 0.0).count() as f64 / gaps.len() as f64;
        let s = Summary::of(&gaps);
        let row = vec![
            name,
            format!("{:.4}", s.mean),
            format!("{:.4}", s.p50),
            format!("{:.4}", s.p99),
            format!("{zero:.2}"),
        ];
        rows.push(row.clone());
        row
    };

    for p in ServerProfile::all() {
        let ep = ServerEndpoint::new(p.clone());
        let gaps = ep.sample_gaps(0, n_tokens, &mut rng);
        let row = push(format!("server/{}", p.name), gaps);
        csv.row(row);
    }
    for p in [
        DeviceProfile::a40_qwen7b(),
        DeviceProfile::rtx3080x2_llama8b(),
    ] {
        let ep = DeviceEndpoint::new(p.clone());
        let gaps = ep.sample_gaps(0, n_tokens, &mut rng);
        let row = push(format!("device/{}", p.name), gaps);
        csv.row(row);
    }
    csv.write(&ctx.csv_path("fig3"))?;
    Ok(render_table(
        &["setup", "mean_tbt", "p50", "p99", "zero_frac"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterization_experiments_run() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_char"),
            n_seeds: 2,
            n_requests: 200,
        };
        let t1 = table1(&ctx).unwrap();
        assert!(t1.contains("Device"));
        let f2 = fig2(&ctx).unwrap();
        assert!(f2.contains("server/GPT"));
        let f3 = fig3(&ctx).unwrap();
        assert!(f3.contains("zero_frac"));
        assert!(ctx.csv_path("table1").exists());
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
