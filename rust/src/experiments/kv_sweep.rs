//! Paged-KV sweep: page-pool size × prefix caching × session load.
//!
//! Each cell runs a multi-user chat workload
//! ([`SessionSpec::chat`]) on a K-shard fleet under
//! [`BatchingMode::PagedKv`]. Cells at the same (users, seed) pair
//! replay the identical trace and latency draws — the paged-KV
//! subsystem draws no randomness of its own — so the cache-on vs
//! cache-off columns and the pool-size columns are paired comparisons:
//! the TTFT gap is a pure memory-model effect. Reported per cell:
//! TTFT/TBT quantiles, the prefix-cache hit rate, memory-pressure
//! preemptions, outage-free forced re-prefills (always zero here; the
//! failover sweep owns outages), and peak page-pool utilization.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::batching::BatchLatencyCurve;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::sim::kv::KvConfig;
use crate::trace::generator::SessionSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the KV-sweep grid.
#[derive(Clone, Copy, Debug)]
pub struct KvCell {
    /// KV block-pool size per shard (pages).
    pub pages: usize,
    /// Whether the cell runs with prefix caching enabled.
    pub cached: bool,
    /// Concurrent chat users (the load axis: aggregate rate is
    /// `users / mean_think`).
    pub users: usize,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct KvCellResult {
    pub cell: KvCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub p99_tbt: f64,
    /// Prefix-cache hit rate (0 when caching is off — disabled gates
    /// count no lookups).
    pub hit_rate: f64,
    /// Memory-pressure preemptions across the run (seed-averaged).
    pub preemptions: f64,
    /// Forced mid-decode re-prefills (outage-driven; zero here).
    pub forced_reprefills: f64,
    /// Peak pages in use over the pool size, worst shard.
    pub peak_page_util: f64,
}

/// Sweep parameters, shared by the `kv-sweep` experiment entry and its
/// tests.
#[derive(Clone, Debug)]
pub struct KvSweepParams {
    pub pages: Vec<usize>,
    pub cached: Vec<bool>,
    pub users: Vec<usize>,
    pub requests_per_user: usize,
    /// Mean think time between a user's consecutive requests (s).
    pub mean_think: f64,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// Prefill tokens admitted per tick per shard (Sarathi chunk).
    pub chunk_tokens: u32,
    pub tick_interval: f64,
    pub curve: BatchLatencyCurve,
    pub shards: usize,
    /// Slot count the `sharded` constructor records (unused by the
    /// paged gate, kept for topology parity with the other sweeps).
    pub slots_per_shard: usize,
    pub balancer: BalancerKind,
    /// Dispatch policy every cell runs (ServerOnly isolates the memory
    /// model from device-race effects).
    pub policy: PolicyKind,
    pub b: f64,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for KvSweepParams {
    fn default() -> Self {
        KvSweepParams {
            // A pool that fits the session working set snugly, one 4×
            // larger, and one effectively unbounded.
            pages: vec![48, 192, 4096],
            cached: vec![true, false],
            users: vec![4, 12],
            requests_per_user: 6,
            mean_think: 2.0,
            block_tokens: 16,
            chunk_tokens: 256,
            tick_interval: 0.25,
            curve: BatchLatencyCurve::Knee {
                knee: 8,
                alpha: 0.05,
            },
            shards: 2,
            slots_per_shard: 2,
            balancer: BalancerKind::JoinShortestQueue,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_seeds: 2,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

impl KvSweepParams {
    /// Number of grid cells.
    pub fn n_cells(&self) -> usize {
        self.pages.len() * self.cached.len() * self.users.len()
    }

    fn kv_config(&self, cell: &KvCell) -> KvConfig {
        KvConfig {
            pages: cell.pages,
            block_tokens: self.block_tokens,
            chunk_tokens: self.chunk_tokens,
            tick_interval: self.tick_interval,
            prefix_caching: cell.cached,
            curve: self.curve,
            ..KvConfig::default()
        }
    }
}

/// The (scenario, trace, policy) triple a (users, seed) pair replays —
/// shared by every (pages, cached) cell at that pair, so pool-size and
/// caching comparisons are paired by construction.
fn cell_workload(
    params: &KvSweepParams,
    users: usize,
    seed: u64,
) -> (Scenario, crate::trace::Trace, crate::coordinator::policy::Policy) {
    let cell_seed = CellSeed::new(seed).mix_u64(users as u64);
    let scenario = Scenario::new(
        params.service.clone(),
        params.device.clone(),
        Constraint::Server,
        SimConfig {
            seed: cell_seed.scenario(),
            ..Default::default()
        },
    );
    let trace = SessionSpec::chat(users, params.requests_per_user, params.mean_think)
        .generate(cell_seed.trace(0xCAC4E));
    let policy = make_policy(
        params.policy,
        params.b,
        false,
        &scenario,
        &trace,
        cell_seed.scenario(),
    );
    (scenario, trace, policy)
}

/// Run the (pages × cached × users) grid in parallel; cells come back
/// in grid order (pages outer, cached middle, users inner).
pub fn run_grid(params: &KvSweepParams) -> Vec<KvCellResult> {
    let mut cells = Vec::with_capacity(params.n_cells());
    for &pages in &params.pages {
        for &cached in &params.cached {
            for &users in &params.users {
                cells.push(KvCell {
                    pages,
                    cached,
                    users,
                });
            }
        }
    }
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &KvSweepParams, cell: &KvCell) -> KvCellResult {
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut p99_tbt = Vec::new();
    let mut hit_rate = Vec::new();
    let mut preemptions = Vec::new();
    let mut forced = Vec::new();
    let mut peak_util = Vec::new();
    for seed in 0..params.n_seeds {
        let (scenario, trace, policy) = cell_workload(params, cell.users, seed);
        let cfg = FleetConfig::sharded(params.shards, params.slots_per_shard, params.balancer)
            .with_kv(params.kv_config(cell));
        let rep = scenario.run_fleet_report(&trace, &policy, &cfg);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        p99_tbt.push(rep.qoe.tbt.p99);
        hit_rate.push(rep.load.prefix_hit_rate().unwrap_or(0.0));
        preemptions.push(rep.load.kv_preemptions as f64);
        forced.push(rep.load.kv_forced_reprefills as f64);
        peak_util.push(
            rep.load
                .shards
                .iter()
                .map(|s| s.kv_pages_peak as f64 / s.kv_pages_total.max(1) as f64)
                .fold(0.0, f64::max),
        );
    }
    let avg = crate::stats::describe::mean;
    KvCellResult {
        cell: *cell,
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        p99_tbt: avg(&p99_tbt),
        hit_rate: avg(&hit_rate),
        preemptions: avg(&preemptions),
        forced_reprefills: avg(&forced),
        peak_page_util: avg(&peak_util),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[KvCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.cell.pages),
                if r.cell.cached { "cache" } else { "nocache" }.to_string(),
                format!("{}", r.cell.users),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.p99_tbt),
                format!("{:.2}", r.hit_rate),
                format!("{:.1}", r.preemptions),
                format!("{:.1}", r.forced_reprefills),
                format!("{:.2}", r.peak_page_util),
            ]
        })
        .collect();
    render_table(
        &[
            "pages",
            "prefix",
            "users",
            "mean TTFT",
            "p99 TTFT",
            "p99 TBT",
            "hit rate",
            "preempt",
            "reprefill",
            "peak util",
        ],
        &rows,
    )
}

/// The `kv-sweep` experiment entry: default grid, CSV + table.
pub fn kv_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = KvSweepParams {
        n_seeds: ctx.n_seeds.clamp(1, 2),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "pages",
        "prefix_caching",
        "users",
        "mean_ttft",
        "p99_ttft",
        "p99_tbt",
        "hit_rate",
        "preemptions",
        "forced_reprefills",
        "peak_page_util",
    ]);
    for r in &results {
        csv.rowd(&[
            format!("{}", r.cell.pages),
            format!("{}", r.cell.cached),
            format!("{}", r.cell.users),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.p99_tbt),
            format!("{:.4}", r.hit_rate),
            format!("{:.2}", r.preemptions),
            format!("{:.2}", r.forced_reprefills),
            format!("{:.4}", r.peak_page_util),
        ]);
    }
    csv.write(&ctx.csv_path("kv-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> KvSweepParams {
        KvSweepParams {
            pages: vec![64, 2048],
            cached: vec![true, false],
            users: vec![6],
            requests_per_user: 5,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_axes_and_caching_helps() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 4);
        // Grid order: pages outer, cached middle, users inner.
        let (small_on, small_off) = (&results[0], &results[1]);
        assert!(small_on.cell.cached && !small_off.cell.cached);
        assert_eq!(small_on.cell.pages, 64);
        assert!(
            small_on.hit_rate > 0.0,
            "session prompts must hit the prefix index"
        );
        assert_eq!(small_off.hit_rate, 0.0, "disabled gates count no lookups");
        // Paired traces: caching can only shrink prefill work.
        assert!(
            small_on.mean_ttft <= small_off.mean_ttft,
            "cache {:.4}s vs nocache {:.4}s",
            small_on.mean_ttft,
            small_off.mean_ttft
        );
        for r in &results {
            assert!(r.mean_ttft > 0.0 && r.p99_ttft >= r.mean_ttft * 0.5);
            // Decode growth may transiently overshoot the pool by a few
            // pages before the preemption loop frees them, so the peak
            // can nose past 1.0 under pressure — never run away.
            assert!(r.peak_page_util > 0.0 && r.peak_page_util < 1.5);
            assert_eq!(r.forced_reprefills, 0.0, "no outages in this sweep");
        }
    }

    #[test]
    fn kv_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_kv_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = kv_sweep(&ctx).unwrap();
        assert!(out.contains("hit rate"));
        let csv = std::fs::read_to_string(ctx.csv_path("kv-sweep")).unwrap();
        // Header + 3 pools × 2 caching modes × 2 user counts.
        assert_eq!(csv.lines().count(), 1 + 12);
        assert_eq!(KvSweepParams::default().n_cells(), 12);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
