//! Zone sweep: scaling one cell across cores with the zone-partitioned
//! fleet (`sim/zones.rs`), over the (zone count × shards-per-zone ×
//! arrival rate) grid.
//!
//! Each cell fixes a zoned topology (Z zones, K shards per zone) and
//! replays a Poisson workload at the target *aggregate* rate — the
//! round-robin partition hands each zone ~rate/Z of it. Cells at the
//! same (K, rate, seed) replay the identical trace whatever Z is, so
//! the sweep isolates what partitioning itself does to tails and
//! utilization (zones cannot balance load across each other — the
//! price of embarrassingly parallel zones). Unlike the other sweeps,
//! the parallelism here is *within* the cell: zones fan out across
//! cores via [`crate::util::par::par_map`], and the merged numbers are
//! byte-identical under any `DISCO_THREADS`.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::sim::zones::{run_zoned_fleet, ZonedFleetConfig};
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the zone-sweep grid.
#[derive(Clone, Debug)]
pub struct ZoneCell {
    pub zones: usize,
    pub shards_per_zone: usize,
    pub rate_rps: f64,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct ZoneCellResult {
    pub cell: ZoneCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub p99_queue_delay: f64,
    pub server_utilization: f64,
    /// Max/mean per-zone server busy-seconds (1.0 = the round-robin
    /// partition loaded every zone equally).
    pub zone_imbalance: f64,
}

/// Sweep parameters, shared by the `zone-sweep` experiment and the
/// `zone_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct ZoneSweepParams {
    pub zone_counts: Vec<usize>,
    pub shards_per_zone: Vec<usize>,
    /// Aggregate arrival rates (req/s across all zones).
    pub rates: Vec<f64>,
    pub slots_per_shard: usize,
    pub balancer: BalancerKind,
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for ZoneSweepParams {
    fn default() -> Self {
        ZoneSweepParams {
            zone_counts: vec![1, 2, 4],
            shards_per_zone: vec![2, 4],
            rates: vec![1.0, 4.0],
            slots_per_shard: 1,
            balancer: BalancerKind::JoinShortestQueue,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_requests: 400,
            n_seeds: 2,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

/// Run the (Z × K × rate) grid; cells run *serially* here because each
/// cell already parallelizes internally across its zones (nesting
/// scoped pools would oversubscribe the machine without changing any
/// result — determinism is thread-count invariant either way).
pub fn run_grid(params: &ZoneSweepParams) -> Vec<ZoneCellResult> {
    let cells: Vec<ZoneCell> = params
        .zone_counts
        .iter()
        .flat_map(|&zones| {
            params.shards_per_zone.iter().flat_map(move |&shards_per_zone| {
                params.rates.iter().map(move |&rate_rps| ZoneCell {
                    zones,
                    shards_per_zone,
                    rate_rps,
                })
            })
        })
        .collect();
    cells.iter().map(|cell| run_cell(params, cell)).collect()
}

fn run_cell(params: &ZoneSweepParams, cell: &ZoneCell) -> ZoneCellResult {
    let fleet = FleetConfig::sharded(cell.shards_per_zone, params.slots_per_shard, params.balancer);
    let zoned = ZonedFleetConfig::uniform(cell.zones, fleet);
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut qd_p99 = Vec::new();
    let mut util = Vec::new();
    let mut imb = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seed over (rate, K) — deliberately NOT over
        // the zone count, so every Z at a (K, rate, seed) cell replays
        // the identical trace (paired comparison of partitioning).
        let cell_seed = CellSeed::new(seed)
            .mix_f64(cell.rate_rps)
            .mix_u64(cell.shards_per_zone as u64);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Server,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let trace = WorkloadSpec::alpaca(params.n_requests)
            .at_rate(cell.rate_rps)
            .generate(cell_seed.trace(0x20ED));
        let policy = make_policy(
            params.policy,
            params.b,
            false,
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let out = run_zoned_fleet(&scenario, &trace, &policy, &zoned);
        let qoe = crate::metrics::Report::from_records(&out.merged.records, policy.constraint());
        mean_ttft.push(qoe.ttft.mean);
        p99_ttft.push(qoe.ttft.p99);
        qd_p99.push(out.merged.load.server_queue_delay.p99);
        util.push(out.merged.load.server_utilization().unwrap_or(0.0));
        let busy: Vec<f64> = out.zone_loads.iter().map(|l| l.server_busy_seconds).collect();
        let mean_busy = crate::stats::describe::mean(&busy);
        imb.push(if mean_busy > 0.0 {
            busy.iter().cloned().fold(f64::NEG_INFINITY, f64::max) / mean_busy
        } else {
            0.0
        });
    }
    let avg = crate::stats::describe::mean;
    ZoneCellResult {
        cell: cell.clone(),
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        p99_queue_delay: avg(&qd_p99),
        server_utilization: avg(&util),
        zone_imbalance: avg(&imb),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[ZoneCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.cell.zones),
                format!("{}", r.cell.shards_per_zone),
                format!("{:.2}", r.cell.rate_rps),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.p99_queue_delay),
                format!("{:.2}", r.server_utilization),
                format!("{:.2}", r.zone_imbalance),
            ]
        })
        .collect();
    render_table(
        &[
            "zones",
            "shards/zone",
            "rate (req/s)",
            "mean TTFT",
            "p99 TTFT",
            "p99 queue",
            "util",
            "zone imb",
        ],
        &rows,
    )
}

/// The `zone-sweep` experiment entry: default grid, CSV + table output.
pub fn zone_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = ZoneSweepParams {
        n_requests: ctx.n_requests.clamp(50, 400),
        n_seeds: ctx.n_seeds.clamp(1, 2),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "zones",
        "shards_per_zone",
        "rate_rps",
        "mean_ttft",
        "p99_ttft",
        "p99_queue_delay",
        "server_utilization",
        "zone_imbalance",
    ]);
    for r in &results {
        csv.rowd(&[
            format!("{}", r.cell.zones),
            format!("{}", r.cell.shards_per_zone),
            format!("{:.3}", r.cell.rate_rps),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.p99_queue_delay),
            format!("{:.4}", r.server_utilization),
            format!("{:.4}", r.zone_imbalance),
        ]);
    }
    csv.write(&ctx.csv_path("zone-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> ZoneSweepParams {
        ZoneSweepParams {
            zone_counts: vec![1, 2],
            shards_per_zone: vec![2],
            rates: vec![0.5, 2.0],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_all_axes_in_order() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.cell.zones, params.zone_counts[i / 2]);
            assert_eq!(r.cell.shards_per_zone, 2);
            assert_eq!(r.cell.rate_rps, params.rates[i % 2]);
            assert!(r.mean_ttft > 0.0);
            assert!(r.server_utilization <= 1.0 + 1e-9);
            assert!(r.zone_imbalance >= if r.cell.zones > 1 { 1.0 } else { 0.0 });
        }
    }

    #[test]
    fn same_cell_reproduces_regardless_of_grid_shape() {
        let solo = run_grid(&ZoneSweepParams {
            zone_counts: vec![2],
            shards_per_zone: vec![2],
            rates: vec![2.0],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        });
        let grid = run_grid(&tiny_params());
        let in_grid = grid
            .iter()
            .find(|r| r.cell.zones == 2 && r.cell.rate_rps == 2.0)
            .unwrap();
        assert_eq!(solo[0].mean_ttft.to_bits(), in_grid.mean_ttft.to_bits());
        assert_eq!(solo[0].p99_ttft.to_bits(), in_grid.p99_ttft.to_bits());
    }

    #[test]
    fn zone_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_zone_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = zone_sweep(&ctx).unwrap();
        assert!(out.contains("zones"));
        let csv = std::fs::read_to_string(ctx.csv_path("zone-sweep")).unwrap();
        // Header + 3 zone counts × 2 shard counts × 2 rates.
        assert_eq!(csv.lines().count(), 1 + 12);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
