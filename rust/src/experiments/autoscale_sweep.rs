//! Autoscale sweep: the capacity-vs-tail-TTFT trade-off across the
//! (scaling policy × arrival rate × cold-start profile) grid.
//!
//! Each cell replays the same bursty Gamma workload (cv > 1, so arrival
//! clumps stress the fleet) through four provisioning strategies: a
//! static fleet at the autoscaler's floor (`static-min`), a static fleet
//! at its ceiling (`static-max`), and the reactive / TTFT-target
//! autoscalers scaling between the two with the cell's cold-start
//! penalty. Policies at the same (rate, seed) see the *same* trace and
//! pre-drawn latency samples, so TTFT and shard-second differences are
//! pure provisioning effects — the ServerlessLLM/SpotServe question
//! ("what does flexible capacity actually cost?") asked of this
//! simulator. Cells fan out via
//! [`crate::experiments::common::par_map`] with [`CellSeed`]
//! content-derived seeding.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::autoscaler::{
    AutoscaleConfig, AutoscalerKind, ColdStartSpec, ReactiveConfig, TtftTargetConfig,
};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::trace::generator::{Arrival, WorkloadSpec};
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// Provisioning strategy axis of the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyAxis {
    /// Static fleet at the autoscaler's floor (`min_shards`).
    StaticMin,
    /// Static fleet at the autoscaler's ceiling (`max_shards`).
    StaticMax,
    /// Reactive queue-depth autoscaler between the two.
    Reactive,
    /// TTFT-target autoscaler between the two.
    TtftTarget,
}

impl PolicyAxis {
    /// All strategies, in report order.
    pub fn all() -> Vec<PolicyAxis> {
        vec![
            PolicyAxis::StaticMin,
            PolicyAxis::StaticMax,
            PolicyAxis::Reactive,
            PolicyAxis::TtftTarget,
        ]
    }

    /// Short label used in tables and CSVs.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyAxis::StaticMin => "static-min",
            PolicyAxis::StaticMax => "static-max",
            PolicyAxis::Reactive => "reactive",
            PolicyAxis::TtftTarget => "ttft-target",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<PolicyAxis> {
        Some(match s.to_ascii_lowercase().as_str() {
            "static-min" | "min" => PolicyAxis::StaticMin,
            "static-max" | "max" => PolicyAxis::StaticMax,
            "reactive" => PolicyAxis::Reactive,
            "ttft" | "ttft-target" => PolicyAxis::TtftTarget,
            _ => return None,
        })
    }

    /// Static fleets never pay a cold start, so these strategies run one
    /// cell per rate instead of one per (rate × cold case).
    pub fn is_static(&self) -> bool {
        matches!(self, PolicyAxis::StaticMin | PolicyAxis::StaticMax)
    }
}

/// One cold-start case of the grid: a labelled load-delay model.
#[derive(Clone, Debug)]
pub struct ColdCase {
    /// Display label (CSV column value).
    pub label: String,
    /// The delay model.
    pub spec: ColdStartSpec,
}

impl ColdCase {
    /// Wrap a spec under its canonical label.
    pub fn new(spec: ColdStartSpec) -> ColdCase {
        ColdCase {
            label: spec.label(),
            spec,
        }
    }
}

/// One cell of the autoscale-sweep grid.
#[derive(Clone, Debug)]
pub struct AutoscaleCell {
    pub policy: PolicyAxis,
    pub rate_rps: f64,
    pub cold: ColdCase,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct AutoscaleCellResult {
    pub cell: AutoscaleCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub p99_queue_delay: f64,
    /// Provisioned shard-seconds (the capacity cost).
    pub shard_seconds: f64,
    /// Seconds spent loading models on scaled-out shards.
    pub cold_start_seconds: f64,
    /// Time-weighted mean warm-shard count.
    pub mean_warm_shards: f64,
    /// Scale-out transitions per run.
    pub scale_outs: f64,
}

/// Sweep parameters, shared by the `autoscale-sweep` experiment and the
/// `autoscale_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct AutoscaleSweepParams {
    pub policies: Vec<PolicyAxis>,
    pub rates: Vec<f64>,
    pub cold_cases: Vec<ColdCase>,
    /// Autoscaler floor; also the `static-min` fleet size.
    pub min_shards: usize,
    /// Autoscaler ceiling; also the `static-max` fleet size.
    pub max_shards: usize,
    /// Concurrent admissions per shard.
    pub slots_per_shard: usize,
    pub balancer: BalancerKind,
    /// Seconds between autoscaler evaluations.
    pub eval_interval: f64,
    /// Gamma arrival cv (> 1 = burstier than Poisson).
    pub burst_cv: f64,
    /// Dispatch policy every cell runs (ServerOnly isolates provisioning
    /// effects from device-race effects).
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for AutoscaleSweepParams {
    fn default() -> Self {
        AutoscaleSweepParams {
            policies: PolicyAxis::all(),
            // Under to past the static-min capacity for the default GPT
            // profile (service ≈ 1.3 s ⇒ ~0.75 rps per slot).
            rates: vec![1.0, 2.5, 4.0],
            cold_cases: vec![
                ColdCase::new(ColdStartSpec::rtx3060_3b()),
                ColdCase::new(ColdStartSpec::a40_7b()),
            ],
            min_shards: 1,
            max_shards: 6,
            slots_per_shard: 1,
            balancer: BalancerKind::JoinShortestQueue,
            eval_interval: 1.0,
            burst_cv: 2.5,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_requests: 400,
            n_seeds: 3,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

impl AutoscaleSweepParams {
    /// Number of grid cells: static strategies contribute one cell per
    /// rate; dynamic ones, one per (rate × cold case).
    pub fn n_cells(&self) -> usize {
        let statics = self.policies.iter().filter(|p| p.is_static()).count();
        let dynamic = self.policies.len() - statics;
        self.rates.len() * (statics + dynamic * self.cold_cases.len())
    }

    /// The fleet configuration a (policy, cold) pair runs.
    fn fleet_for(&self, policy: PolicyAxis, cold: &ColdCase) -> FleetConfig {
        let autoscale = |kind: AutoscalerKind| AutoscaleConfig {
            kind,
            eval_interval: self.eval_interval,
            min_shards: self.min_shards,
            max_shards: self.max_shards,
            cold_start: cold.spec,
        };
        match policy {
            PolicyAxis::StaticMin => {
                FleetConfig::sharded(self.min_shards, self.slots_per_shard, self.balancer)
            }
            PolicyAxis::StaticMax => {
                FleetConfig::sharded(self.max_shards, self.slots_per_shard, self.balancer)
            }
            PolicyAxis::Reactive => {
                let kind = AutoscalerKind::Reactive(ReactiveConfig::default());
                FleetConfig::sharded(self.min_shards, self.slots_per_shard, self.balancer)
                    .with_autoscale(autoscale(kind))
            }
            PolicyAxis::TtftTarget => {
                let kind = AutoscalerKind::TtftTarget(TtftTargetConfig::default());
                FleetConfig::sharded(self.min_shards, self.slots_per_shard, self.balancer)
                    .with_autoscale(autoscale(kind))
            }
        }
    }
}

/// Run the (policy × rate × cold-start) grid in parallel; cells come back
/// in grid order (policies outer, rates middle, cold cases inner).
/// Static strategies ignore the cold-start axis, so they contribute one
/// cell per rate (labelled `n/a`) instead of duplicating identical runs
/// across every cold case.
pub fn run_grid(params: &AutoscaleSweepParams) -> Vec<AutoscaleCellResult> {
    let mut cells: Vec<AutoscaleCell> = Vec::with_capacity(params.n_cells());
    for &policy in &params.policies {
        for &rate_rps in &params.rates {
            if policy.is_static() {
                cells.push(AutoscaleCell {
                    policy,
                    rate_rps,
                    cold: ColdCase {
                        label: "n/a".to_string(),
                        spec: ColdStartSpec::Fixed(0.0),
                    },
                });
            } else {
                for cold in &params.cold_cases {
                    cells.push(AutoscaleCell {
                        policy,
                        rate_rps,
                        cold: cold.clone(),
                    });
                }
            }
        }
    }
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &AutoscaleSweepParams, cell: &AutoscaleCell) -> AutoscaleCellResult {
    let fleet = params.fleet_for(cell.policy, &cell.cold);
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut qd_p99 = Vec::new();
    let mut shard_secs = Vec::new();
    let mut cold_secs = Vec::new();
    let mut warm = Vec::new();
    let mut outs = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seed over the rate only — every policy and
        // cold-start case at a (rate, seed) cell replays the identical
        // trace and latency draws (paired comparison).
        let cell_seed = CellSeed::new(seed).mix_f64(cell.rate_rps);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Server,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Gamma {
                mean_gap: 1.0 / cell.rate_rps,
                cv: params.burst_cv,
            },
            ..WorkloadSpec::alpaca(params.n_requests)
        };
        let trace = spec.generate(cell_seed.trace(0xA5CA1E));
        let policy = make_policy(
            params.policy,
            params.b,
            false,
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let rep = scenario.run_fleet_report(&trace, &policy, &fleet);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        qd_p99.push(rep.load.server_queue_delay.p99);
        shard_secs.push(rep.load.shard_seconds);
        cold_secs.push(rep.load.cold_start_seconds);
        warm.push(rep.load.mean_warm_shards());
        outs.push(rep.load.scale_out_count() as f64);
    }
    let avg = crate::stats::describe::mean;
    AutoscaleCellResult {
        cell: cell.clone(),
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        p99_queue_delay: avg(&qd_p99),
        shard_seconds: avg(&shard_secs),
        cold_start_seconds: avg(&cold_secs),
        mean_warm_shards: avg(&warm),
        scale_outs: avg(&outs),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[AutoscaleCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.cell.policy.label().to_string(),
                format!("{:.2}", r.cell.rate_rps),
                r.cell.cold.label.clone(),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.p99_queue_delay),
                format!("{:.0}", r.shard_seconds),
                format!("{:.1}", r.cold_start_seconds),
                format!("{:.2}", r.mean_warm_shards),
                format!("{:.1}", r.scale_outs),
            ]
        })
        .collect();
    render_table(
        &[
            "policy",
            "rate (req/s)",
            "cold-start",
            "mean TTFT",
            "p99 TTFT",
            "p99 queue",
            "shard-sec",
            "cold-sec",
            "mean warm",
            "scale-outs",
        ],
        &rows,
    )
}

/// The `autoscale-sweep` experiment entry: default grid, CSV + table.
pub fn autoscale_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = AutoscaleSweepParams {
        n_requests: ctx.n_requests.clamp(50, 400),
        n_seeds: ctx.n_seeds.clamp(1, 3),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "policy",
        "rate_rps",
        "cold_start",
        "mean_ttft",
        "p99_ttft",
        "p99_queue_delay",
        "shard_seconds",
        "cold_start_seconds",
        "mean_warm_shards",
        "scale_outs",
    ]);
    for r in &results {
        csv.rowd(&[
            r.cell.policy.label().to_string(),
            format!("{:.3}", r.cell.rate_rps),
            r.cell.cold.label.clone(),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.p99_queue_delay),
            format!("{:.2}", r.shard_seconds),
            format!("{:.2}", r.cold_start_seconds),
            format!("{:.3}", r.mean_warm_shards),
            format!("{:.2}", r.scale_outs),
        ]);
    }
    csv.write(&ctx.csv_path("autoscale-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> AutoscaleSweepParams {
        AutoscaleSweepParams {
            policies: vec![PolicyAxis::StaticMin, PolicyAxis::Reactive],
            rates: vec![2.0],
            cold_cases: vec![ColdCase::new(ColdStartSpec::Fixed(1.0))],
            max_shards: 3,
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_axes_and_pairs_traces() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].cell.policy, PolicyAxis::StaticMin);
        assert_eq!(results[1].cell.policy, PolicyAxis::Reactive);
        // Static cells never scale and bill exactly K × horizon; the
        // reactive cell at an overloaded rate scales out.
        assert_eq!(results[0].scale_outs, 0.0);
        assert_eq!(results[0].cold_start_seconds, 0.0);
        assert!(results[1].scale_outs >= 1.0);
        assert!(results[1].cold_start_seconds > 0.0);
        // Same trace, ~3× the capacity once scaled: the autoscaler must
        // clearly beat the overloaded floor fleet on tail TTFT. (Not a
        // zero-tolerance monotonicity claim — multi-queue reassignment
        // can move individual delays either way — but at 2 req/s against
        // a one-shard fleet the backlog gap is severalfold.)
        assert!(
            results[1].p99_ttft < 0.95 * results[0].p99_ttft,
            "reactive p99 {:.2}s should clearly beat static-min {:.2}s",
            results[1].p99_ttft,
            results[0].p99_ttft
        );
    }

    #[test]
    fn policy_axis_parse_roundtrips() {
        for p in PolicyAxis::all() {
            assert_eq!(PolicyAxis::parse(p.label()), Some(p));
        }
        assert_eq!(PolicyAxis::parse("ttft"), Some(PolicyAxis::TtftTarget));
        assert!(PolicyAxis::parse("nope").is_none());
    }

    #[test]
    fn autoscale_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_autoscale_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = autoscale_sweep(&ctx).unwrap();
        assert!(out.contains("policy"));
        let csv = std::fs::read_to_string(ctx.csv_path("autoscale-sweep")).unwrap();
        // Header + 2 static policies × 3 rates + 2 dynamic policies ×
        // 3 rates × 2 cold cases.
        assert_eq!(csv.lines().count(), 1 + 18);
        assert_eq!(AutoscaleSweepParams::default().n_cells(), 18);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
