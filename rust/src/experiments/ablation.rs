//! §5.3 ablations: Fig 5 (DiffusionDB sending intervals) and Fig 9
//! (scheduler overhead scalability).

use crate::coordinator::dispatch::{DeviceConstrainedPlan, ServerConstrainedPlan};
use crate::coordinator::policy::{Policy, PolicyKind};
use crate::cost::unified::Constraint;
use crate::experiments::common::*;
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::engine::{Scenario, SimConfig};
use crate::stats::ecdf::Ecdf;
use crate::stats::fit::LogNormalFit;
use crate::trace::diffusiondb;
use crate::util::csv::CsvWriter;
use crate::util::render_table;
use crate::util::rng::Rng;
use std::time::Instant;

/// Fig 5: mean-TTFT reduction across DiffusionDB user activity levels
/// (real-world request intervals × Alpaca prompts).
///
/// Reported in BOTH regimes: `replay` matches the paper's methodology
/// (per-request latencies replayed independently — Fig 5's claim
/// reproduces); `queueing` additionally models single-flight device
/// occupancy, where the reproduction surfaces a finding the paper does
/// not discuss: for users with sub-10 s gaps the device saturates and
/// the advantage inverts (see EXPERIMENTS.md).
pub fn fig5(ctx: &ExpContext) -> anyhow::Result<String> {
    let service = ServerProfile::gpt4o_mini();
    let device = DeviceProfile::pixel7pro_bloom1b1();
    let b = 0.5;
    let per_user = (ctx.n_requests / 5).max(50);
    let mut csv = CsvWriter::new(&[
        "regime",
        "user",
        "median_gap_s",
        "disco_mean_ttft",
        "stoch_mean_ttft",
        "reduction_pct",
    ]);
    let mut rows = Vec::new();
    for (regime, queueing) in [("replay", false), ("queueing", true)] {
        for user in diffusiondb::ten_users() {
            let mut disco_means = Vec::new();
            let mut stoch_means = Vec::new();
            for seed in 0..ctx.n_seeds {
                let trace = diffusiondb::user_trace(&user, per_user, seed);
                let scenario = Scenario::new(
                    service.clone(),
                    device.clone(),
                    Constraint::Server,
                    SimConfig {
                        seed,
                        device_queueing: queueing,
                        ..Default::default()
                    },
                );
                let disco = make_policy(PolicyKind::DiscoS, b, false, &scenario, &trace, seed);
                let stoch = Policy::simple(PolicyKind::StochS, b, false);
                disco_means.push(scenario.run_report(&trace, &disco).ttft.mean);
                stoch_means.push(scenario.run_report(&trace, &stoch).ttft.mean);
            }
            let dm = crate::stats::describe::mean(&disco_means);
            let sm = crate::stats::describe::mean(&stoch_means);
            let red = (sm - dm) / sm * 100.0;
            csv.rowd(&[
                regime.to_string(),
                format!("u{}", user.user_id),
                format!("{:.1}", user.median_gap),
                format!("{dm:.3}"),
                format!("{sm:.3}"),
                format!("{red:.1}"),
            ]);
            rows.push(vec![
                regime.to_string(),
                format!("u{}", user.user_id),
                format!("{:.1}", user.median_gap),
                format!("{dm:.3}"),
                format!("{sm:.3}"),
                format!("{red:.1}%"),
            ]);
        }
    }
    csv.write(&ctx.csv_path("fig5"))?;
    Ok(render_table(
        &[
            "regime",
            "user",
            "median gap (s)",
            "DiSCo mean TTFT",
            "Stoch mean TTFT",
            "reduction",
        ],
        &rows,
    ))
}

/// Fig 9: scheduler overhead — wall-clock to plan + decide over 1K/10K/
/// 100K requests whose lengths/TTFTs follow log-normal fits of a real
/// trace (the paper's synthetic-data methodology, §5.3).
pub fn fig9(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["policy", "n_samples", "total_ms", "per_request_us"]);
    let mut rows = Vec::new();
    // Log-normal fits of a GPT trace (lengths + TTFT), per the paper.
    let mut rng = Rng::new(99);
    let service = ServerProfile::gpt4o_mini();
    let ttft_fit = LogNormalFit::fit(
        &(0..2000)
            .map(|_| service.sample_ttft(&mut rng))
            .collect::<Vec<_>>(),
    );
    let len_fit = LogNormalFit { mu: 3.0, sigma: 0.9 };

    for &n in &[1_000usize, 10_000, 100_000] {
        let ttfts: Vec<f64> = ttft_fit.sample_n(&mut rng, 2000);
        let lens: Vec<u32> = (0..n)
            .map(|_| (len_fit.sample(&mut rng).round() as u32).clamp(1, 4096))
            .collect();

        // DiSCo-S: plan once (Eq. 3) + one decide per request.
        let t0 = Instant::now();
        let plan_s = ServerConstrainedPlan::plan(&lens, 0.5);
        let mut acc = 0u64;
        for &l in &lens {
            acc += matches!(
                plan_s.decide(l),
                crate::coordinator::dispatch::Decision::DeviceOnly
            ) as u64;
        }
        let ms_s = t0.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(acc);

        // DiSCo-D: ECDF + Algorithm 2 plan + one wait lookup per request.
        let t0 = Instant::now();
        let ecdf = Ecdf::new(ttfts.clone());
        let plan_d = DeviceConstrainedPlan::plan(&ecdf, &lens, 0.5, 0.05);
        let mut acc = 0.0f64;
        for &l in &lens {
            acc += plan_d.wait_for(l);
        }
        let ms_d = t0.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(acc);

        for (name, ms) in [("DiSCo-S", ms_s), ("DiSCo-D", ms_d)] {
            csv.rowd(&[
                name.to_string(),
                n.to_string(),
                format!("{ms:.3}"),
                format!("{:.3}", ms * 1e3 / n as f64),
            ]);
            rows.push(vec![
                name.to_string(),
                n.to_string(),
                format!("{ms:.3} ms"),
                format!("{:.3} µs", ms * 1e3 / n as f64),
            ]);
        }
    }
    csv.write(&ctx.csv_path("fig9"))?;
    Ok(render_table(
        &["policy", "samples", "total time", "per request"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_overhead_is_trivial() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_abl"),
            n_seeds: 1,
            n_requests: 100,
        };
        let out = fig9(&ctx).unwrap();
        assert!(out.contains("DiSCo-S"));
        // The paper's headline: ~0.1–15 ms. Parse our own CSV and check
        // the 1K case stays under 50 ms even in debug CI noise.
        let csv = std::fs::read_to_string(ctx.csv_path("fig9")).unwrap();
        let line = csv.lines().find(|l| l.starts_with("DiSCo-S,1000")).unwrap();
        let total_ms: f64 = line.split(',').nth(2).unwrap().parse().unwrap();
        assert!(total_ms < 50.0, "1K dispatch took {total_ms} ms");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
