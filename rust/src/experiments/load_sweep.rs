//! Fleet load sweep: a Fig-5-style activity-level scan, but through the
//! discrete-event fleet simulator instead of independent replay.
//!
//! Each grid cell is (arrival rate × policy): a Poisson workload at the
//! target aggregate rate runs against a bounded server admission pool and
//! the single-flight device, and the cell reports load-dependent QoE —
//! mean/p99 TTFT *including* queue delay, the queue delay itself, and
//! server utilization. Cells fan out across cores via
//! [`crate::experiments::common::par_map`] with per-cell deterministic
//! seeding, so the wall clock drops by ≈ #cores while results stay
//! bit-reproducible.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the sweep grid.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub rate_rps: f64,
    pub kind: PolicyKind,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: GridCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub p99_tbt: f64,
    pub mean_queue_delay: f64,
    pub p99_queue_delay: f64,
    pub server_utilization: f64,
}

/// Sweep parameters, shared by the `load-sweep` experiment and the
/// `fleet_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct SweepParams {
    pub rates: Vec<f64>,
    pub policies: Vec<PolicyKind>,
    /// Concurrent admissions per server shard.
    pub server_slots: usize,
    /// Server shard count (1 = the single-pool fleet).
    pub shards: usize,
    /// Balancer fronting the shards (irrelevant at `shards == 1`).
    pub balancer: BalancerKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for SweepParams {
    fn default() -> Self {
        SweepParams {
            // Activity levels from idle chat to saturation (requests/s).
            rates: vec![0.05, 0.2, 0.5, 1.0, 2.0],
            policies: vec![
                PolicyKind::ServerOnly,
                PolicyKind::DeviceOnly,
                PolicyKind::StochS,
                PolicyKind::DiscoS,
            ],
            server_slots: 2,
            shards: 1,
            balancer: BalancerKind::RoundRobin,
            b: 0.5,
            n_requests: 400,
            n_seeds: 3,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

/// Run the (rate × policy) grid in parallel; cells come back in grid
/// order (rates outer, policies inner).
pub fn run_grid(params: &SweepParams) -> Vec<CellResult> {
    let cells: Vec<GridCell> = params
        .rates
        .iter()
        .flat_map(|&rate_rps| {
            params
                .policies
                .iter()
                .map(move |&kind| GridCell { rate_rps, kind })
        })
        .collect();
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &SweepParams, cell: &GridCell) -> CellResult {
    let fleet = FleetConfig {
        server_slots: Some(params.server_slots),
        device_queueing: true,
        shards: params.shards,
        balancer: params.balancer,
        ..FleetConfig::replay(true)
    };
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut p99_tbt = Vec::new();
    let mut qd_mean = Vec::new();
    let mut qd_p99 = Vec::new();
    let mut util = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seeding (see `CellSeed`): policies at the same
        // rate run against the same trace — paired comparisons, not
        // unpaired variance.
        let cell_seed = CellSeed::new(seed).mix_f64(cell.rate_rps);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Server,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let trace = WorkloadSpec::alpaca(params.n_requests)
            .at_rate(cell.rate_rps)
            .generate(cell_seed.trace(0xF1EE7));
        let policy = make_policy(
            cell.kind,
            params.b,
            false,
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let rep = scenario.run_fleet_report(&trace, &policy, &fleet);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        p99_tbt.push(rep.qoe.tbt.p99);
        qd_mean.push(rep.load.server_queue_delay.mean);
        qd_p99.push(rep.load.server_queue_delay.p99);
        util.push(rep.load.server_utilization().unwrap_or(0.0));
    }
    let avg = crate::stats::describe::mean;
    CellResult {
        cell: cell.clone(),
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        p99_tbt: avg(&p99_tbt),
        mean_queue_delay: avg(&qd_mean),
        p99_queue_delay: avg(&qd_p99),
        server_utilization: avg(&util),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[CellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                format!("{:.2}", r.cell.rate_rps),
                r.cell.kind.label().to_string(),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.mean_queue_delay),
                format!("{:.3}", r.p99_queue_delay),
                format!("{:.2}", r.server_utilization),
            ]
        })
        .collect();
    render_table(
        &[
            "rate (req/s)",
            "policy",
            "mean TTFT",
            "p99 TTFT",
            "mean queue",
            "p99 queue",
            "server util",
        ],
        &rows,
    )
}

/// The `load-sweep` experiment entry: default grid, CSV + table output.
pub fn load_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = SweepParams {
        n_requests: ctx.n_requests.clamp(50, 400),
        n_seeds: ctx.n_seeds.clamp(1, 3),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "rate_rps",
        "policy",
        "mean_ttft",
        "p99_ttft",
        "p99_tbt",
        "mean_queue_delay",
        "p99_queue_delay",
        "server_utilization",
    ]);
    for r in &results {
        csv.rowd(&[
            format!("{:.3}", r.cell.rate_rps),
            r.cell.kind.label().to_string(),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.p99_tbt),
            format!("{:.4}", r.mean_queue_delay),
            format!("{:.4}", r.p99_queue_delay),
            format!("{:.4}", r.server_utilization),
        ]);
    }
    csv.write(&ctx.csv_path("load-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> SweepParams {
        SweepParams {
            rates: vec![0.05, 0.5, 2.0],
            policies: vec![PolicyKind::ServerOnly, PolicyKind::StochS],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_rates_times_policies_in_order() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.cell.rate_rps, params.rates[i / 2]);
            assert_eq!(r.cell.kind, params.policies[i % 2]);
            assert!(r.mean_ttft > 0.0);
            assert!(r.p99_ttft >= r.mean_ttft * 0.5);
        }
    }

    #[test]
    fn same_cell_reproduces_regardless_of_grid_shape() {
        // A cell's numbers must depend on its content, not its position:
        // the (0.5 rps, ServerOnly) cell from a 1-rate grid and from a
        // 3-rate grid must be bit-identical.
        let solo = run_grid(&SweepParams {
            rates: vec![0.5],
            policies: vec![PolicyKind::ServerOnly],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        });
        let grid = run_grid(&tiny_params());
        let in_grid = grid
            .iter()
            .find(|r| r.cell.rate_rps == 0.5 && r.cell.kind == PolicyKind::ServerOnly)
            .unwrap();
        assert_eq!(solo[0].mean_ttft.to_bits(), in_grid.mean_ttft.to_bits());
        assert_eq!(solo[0].p99_ttft.to_bits(), in_grid.p99_ttft.to_bits());
        assert_eq!(
            solo[0].mean_queue_delay.to_bits(),
            in_grid.mean_queue_delay.to_bits()
        );
    }

    #[test]
    fn load_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_load_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = load_sweep(&ctx).unwrap();
        assert!(out.contains("rate (req/s)"));
        let csv = std::fs::read_to_string(ctx.csv_path("load-sweep")).unwrap();
        // Header + 5 rates × 4 policies.
        assert_eq!(csv.lines().count(), 1 + 20);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
