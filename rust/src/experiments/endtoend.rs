//! §5.2 end-to-end: Fig 6 (mean TTFT vs budget) and Table 2 (tail TTFT
//! reduction vs stochastic dispatching).

use crate::cost::unified::Constraint;
use crate::experiments::common::*;
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// Fig 6: mean TTFT vs budget ratio, per trace × constraint × policy.
pub fn fig6(ctx: &ExpContext) -> anyhow::Result<String> {
    let device = DeviceProfile::pixel7pro_bloom1b1();
    let mut csv = CsvWriter::new(&[
        "service",
        "constraint",
        "b",
        "policy",
        "mean_ttft",
        "p99_ttft",
    ]);
    let mut rows = Vec::new();
    for service in ServerProfile::all() {
        for constraint in [Constraint::Server, Constraint::Device] {
            let mut disco_means = Vec::new();
            let mut stoch_means = Vec::new();
            for &b in &BUDGET_GRID {
                let disco = disco_for(constraint);
                let stoch = stoch_for(constraint);
                for kind in [disco, stoch] {
                    let reports = run_cell(
                        &service,
                        &device,
                        constraint,
                        kind,
                        b,
                        false,
                        ctx.n_requests,
                        ctx.n_seeds,
                    );
                    let mean = avg_mean_ttft(&reports);
                    if kind == disco {
                        disco_means.push(mean);
                    } else {
                        stoch_means.push(mean);
                    }
                    csv.rowd(&[
                        service.name.to_string(),
                        constraint_name(constraint).to_string(),
                        format!("{b:.1}"),
                        kind.label().to_string(),
                        format!("{mean:.4}"),
                        format!("{:.4}", avg_p99_ttft(&reports)),
                    ]);
                }
            }
            // Summary row: averaged improvement across the budget grid.
            let dm = crate::stats::describe::mean(&disco_means);
            let sm = crate::stats::describe::mean(&stoch_means);
            rows.push(vec![
                service.name.to_string(),
                constraint_name(constraint).to_string(),
                format!("{dm:.3}"),
                format!("{sm:.3}"),
                format!("{:.1}%", (sm - dm) / sm * 100.0),
            ]);
        }
    }
    csv.write(&ctx.csv_path("fig6"))?;
    Ok(render_table(
        &[
            "service",
            "constraint",
            "DiSCo mean TTFT",
            "Stoch mean TTFT",
            "reduction",
        ],
        &rows,
    ))
}

/// Table 2: average tail-TTFT reduction vs stochastic dispatching across
/// the whole budget range, per service × device × constraint.
pub fn table2(ctx: &ExpContext) -> anyhow::Result<String> {
    let devices = DeviceProfile::all_mobile();
    let mut csv = CsvWriter::new(&[
        "service",
        "constraint",
        "device",
        "tail_reduction_pct",
    ]);
    let mut rows = Vec::new();
    for service in ServerProfile::all() {
        for constraint in [Constraint::Server, Constraint::Device] {
            let mut row = vec![
                service.name.to_string(),
                constraint_name(constraint).to_string(),
            ];
            for device in &devices {
                let mut reductions = Vec::new();
                for &b in &BUDGET_GRID {
                    let d = run_cell(
                        &service,
                        device,
                        constraint,
                        disco_for(constraint),
                        b,
                        false,
                        ctx.n_requests,
                        ctx.n_seeds,
                    );
                    let s = run_cell(
                        &service,
                        device,
                        constraint,
                        stoch_for(constraint),
                        b,
                        false,
                        ctx.n_requests,
                        ctx.n_seeds,
                    );
                    let (dp, sp) = (avg_p99_ttft(&d), avg_p99_ttft(&s));
                    if sp > 0.0 {
                        reductions.push((sp - dp) / sp * 100.0);
                    }
                }
                let avg = crate::stats::describe::mean(&reductions);
                csv.rowd(&[
                    service.name.to_string(),
                    constraint_name(constraint).to_string(),
                    device.name.to_string(),
                    format!("{avg:.2}"),
                ]);
                row.push(format!("{avg:.2}%"));
            }
            rows.push(row);
        }
    }
    csv.write(&ctx.csv_path("table2"))?;
    Ok(render_table(
        &[
            "service",
            "constraint",
            "Pixel7Pro B-1.1B",
            "Pixel7Pro B-560M",
            "Xiaomi14 Q-0.5B",
        ],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_smoke() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_e2e"),
            n_seeds: 1,
            n_requests: 80,
        };
        let out = fig6(&ctx).unwrap();
        assert!(out.contains("DiSCo"));
        assert!(ctx.csv_path("fig6").exists());
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
