//! Extension ablations beyond the paper's figures — the design choices
//! DESIGN.md calls out, each isolated with everything else held fixed:
//!
//! * `abl-alpha`  — Phase-1 tail-protection reservation α (§4.2)
//! * `abl-buffer` — Eq. 5 buffer sizing (scale 0 → no masking)
//! * `abl-rc`     — consumption-rate sensitivity of TBT/delays
//! * `abl-smooth` — Algorithm-2 stepwise waits vs Eq. 1–2 smooth β waits

use crate::coordinator::migration::MigrationConfig;
use crate::coordinator::policy::{Policy, PolicyKind};
use crate::cost::unified::Constraint;
use crate::experiments::common::make_policy;
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::engine::{Scenario, SimConfig};
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

fn scenario_with(constraint: Constraint, seed: u64, migration: MigrationConfig) -> Scenario {
    Scenario::new(
        ServerProfile::gpt4o_mini(),
        DeviceProfile::pixel7pro_bloom1b1(),
        constraint,
        SimConfig {
            seed,
            migration,
            ..Default::default()
        },
    )
}

/// α sweep: a larger tail reservation spends more budget on w_tail
/// protection and less on immediate device starts.
pub fn abl_alpha(ctx: &ExpContext) -> anyhow::Result<String> {
    let b = 0.3;
    let mut csv = CsvWriter::new(&["alpha", "mean_ttft", "p99_ttft", "budget_frac"]);
    let mut rows = Vec::new();
    for alpha in [0.0, 0.02, 0.05, 0.1, 0.2] {
        let mut means = Vec::new();
        let mut p99s = Vec::new();
        let mut fracs = Vec::new();
        for seed in 0..ctx.n_seeds {
            let sc = scenario_with(Constraint::Device, seed, MigrationConfig::default());
            let trace = WorkloadSpec::alpaca(ctx.n_requests).generate(seed ^ 0xA1FA);
            let ecdf = sc.profile_server_ttft(2000, seed);
            let policy = Policy::plan_with_alpha(
                PolicyKind::DiscoD,
                b,
                false,
                &ecdf,
                &trace.prompt_lens(),
                alpha,
            );
            let r = sc.run_report(&trace, &policy);
            means.push(r.ttft.mean);
            p99s.push(r.ttft.p99);
            fracs.push(r.constrained_prefill_fraction.unwrap_or(0.0));
        }
        let cells = vec![
            format!("{alpha}"),
            format!("{:.4}", crate::stats::describe::mean(&means)),
            format!("{:.4}", crate::stats::describe::mean(&p99s)),
            format!("{:.3}", crate::stats::describe::mean(&fracs)),
        ];
        csv.row(cells.clone());
        rows.push(cells);
    }
    csv.write(&ctx.csv_path("abl-alpha"))?;
    Ok(render_table(
        &["alpha", "mean TTFT", "p99 TTFT", "budget frac"],
        &rows,
    ))
}

/// Eq. 5 buffer-scale ablation: under-buffering must delay tokens.
pub fn abl_buffer(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["buffer_scale", "delay_mean", "delay_p99", "tbt_p99"]);
    let mut rows = Vec::new();
    for scale in [0.0, 0.5, 1.0, 2.0] {
        let mut dmeans = Vec::new();
        let mut dp99s = Vec::new();
        let mut tbts = Vec::new();
        for seed in 0..ctx.n_seeds {
            let cfg = MigrationConfig {
                buffer_scale: scale,
                ..Default::default()
            };
            let sc = scenario_with(Constraint::Device, seed, cfg);
            let trace = WorkloadSpec::alpaca(ctx.n_requests).generate(seed ^ 0xA1FA);
            let policy = make_policy(PolicyKind::DiscoD, 0.6, true, &sc, &trace, seed);
            let r = sc.run_report(&trace, &policy);
            dmeans.push(r.delay_num_mean);
            dp99s.push(r.delay_num_p99);
            tbts.push(r.tbt.p99);
        }
        let cells = vec![
            format!("{scale}"),
            format!("{:.3}", crate::stats::describe::mean(&dmeans)),
            format!("{:.3}", crate::stats::describe::mean(&dp99s)),
            format!("{:.4}", crate::stats::describe::mean(&tbts)),
        ];
        csv.row(cells.clone());
        rows.push(cells);
    }
    csv.write(&ctx.csv_path("abl-buffer"))?;
    Ok(render_table(
        &["buffer scale", "delay_num mean", "delay_num p99", "TBT p99"],
        &rows,
    ))
}

/// Consumption-rate sensitivity.
pub fn abl_rc(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["r_c", "tbt_p99", "delay_mean", "migrated"]);
    let mut rows = Vec::new();
    for rc in [3.0, 4.0, 5.0, 8.0] {
        let mut tbts = Vec::new();
        let mut dmeans = Vec::new();
        let mut migs = Vec::new();
        for seed in 0..ctx.n_seeds {
            let cfg = MigrationConfig {
                consumption_rate: rc,
                ..Default::default()
            };
            let sc = scenario_with(Constraint::Device, seed, cfg);
            let trace = WorkloadSpec::alpaca(ctx.n_requests).generate(seed ^ 0xA1FA);
            let policy = make_policy(PolicyKind::DiscoD, 0.6, true, &sc, &trace, seed);
            let r = sc.run_report(&trace, &policy);
            tbts.push(r.tbt.p99);
            dmeans.push(r.delay_num_mean);
            migs.push(r.migrated_requests as f64);
        }
        let cells = vec![
            format!("{rc}"),
            format!("{:.4}", crate::stats::describe::mean(&tbts)),
            format!("{:.3}", crate::stats::describe::mean(&dmeans)),
            format!("{:.0}", crate::stats::describe::mean(&migs)),
        ];
        csv.row(cells.clone());
        rows.push(cells);
    }
    csv.write(&ctx.csv_path("abl-rc"))?;
    Ok(render_table(
        &["r_c (tok/s)", "TBT p99", "delay mean", "migrated/run"],
        &rows,
    ))
}

/// Stepwise (Algorithm 2) vs smooth (Eq. 1–2) device-constrained waits.
pub fn abl_smooth(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["b", "policy", "mean_ttft", "p99_ttft", "budget_frac"]);
    let mut rows = Vec::new();
    for &b in &[0.2, 0.4, 0.6, 0.8] {
        for kind in [PolicyKind::DiscoD, PolicyKind::DiscoDSmooth] {
            let mut means = Vec::new();
            let mut p99s = Vec::new();
            let mut fracs = Vec::new();
            for seed in 0..ctx.n_seeds {
                let sc = scenario_with(Constraint::Device, seed, MigrationConfig::default());
                let trace = WorkloadSpec::alpaca(ctx.n_requests).generate(seed ^ 0xA1FA);
                let policy = make_policy(kind, b, false, &sc, &trace, seed);
                let r = sc.run_report(&trace, &policy);
                means.push(r.ttft.mean);
                p99s.push(r.ttft.p99);
                fracs.push(r.constrained_prefill_fraction.unwrap_or(0.0));
            }
            let cells = vec![
                format!("{b}"),
                kind.label().to_string(),
                format!("{:.4}", crate::stats::describe::mean(&means)),
                format!("{:.4}", crate::stats::describe::mean(&p99s)),
                format!("{:.3}", crate::stats::describe::mean(&fracs)),
            ];
            csv.row(cells.clone());
            rows.push(cells);
        }
    }
    csv.write(&ctx.csv_path("abl-smooth"))?;
    Ok(render_table(
        &["b", "policy", "mean TTFT", "p99 TTFT", "budget frac"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx(tag: &str) -> ExpContext {
        ExpContext {
            out_dir: std::env::temp_dir().join(format!("disco_abl_{tag}")),
            n_seeds: 1,
            n_requests: 150,
        }
    }

    #[test]
    fn buffer_ablation_shows_masking_effect() {
        let ctx = quick_ctx("buf");
        abl_buffer(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.csv_path("abl-buffer")).unwrap();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').skip(1).map(|c| c.parse().unwrap()).collect())
            .collect();
        // delay_num mean with no buffer (scale 0) ≥ with full buffer.
        assert!(
            rows[0][0] >= rows[2][0],
            "no-buffer delays {} < full-buffer {}",
            rows[0][0],
            rows[2][0]
        );
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }

    #[test]
    fn smooth_ablation_budget_compliance() {
        let ctx = quick_ctx("smooth");
        abl_smooth(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.csv_path("abl-smooth")).unwrap();
        for line in csv.lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            let b: f64 = cols[0].parse().unwrap();
            let frac: f64 = cols[4].parse().unwrap();
            assert!(frac <= b + 0.1, "line {line}");
        }
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }

    #[test]
    fn alpha_ablation_runs() {
        let ctx = quick_ctx("alpha");
        let out = abl_alpha(&ctx).unwrap();
        assert!(out.contains("p99"));
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
