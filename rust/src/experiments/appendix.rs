//! Appendix tables: cold start (Table 4), predictors (Table 5), FLOPs
//! (Tables 6–7), pricing (Table 8).

use crate::cost::flops::ModelArch;
use crate::cost::pricing::PRICING_TABLE;
use crate::endpoint::coldstart::{ColdStartProfile, QWEN_SIZES_B};
use crate::experiments::ExpContext;
use crate::predictor::{evaluate, table5_predictors};
use crate::profiles::server::ServerProfile;
use crate::util::csv::CsvWriter;
use crate::util::render_table;
use crate::util::rng::Rng;

/// Table 4: cold-start load time vs warm TTFT across model sizes.
pub fn table4(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["platform", "model", "load_time_s", "ttft_s", "fits"]);
    let mut rows = Vec::new();
    for p in [ColdStartProfile::rtx3060(), ColdStartProfile::a40()] {
        for (name, b) in QWEN_SIZES_B {
            let fits = p.fits(*b);
            let (load, ttft) = if fits {
                (format!("{:.2}", p.load_time(*b)), format!("{:.3}", p.warm_ttft(*b)))
            } else {
                ("-".into(), "-".into())
            };
            csv.rowd(&[
                p.platform.to_string(),
                name.to_string(),
                load.clone(),
                ttft.clone(),
                fits.to_string(),
            ]);
            rows.push(vec![p.platform.to_string(), name.to_string(), load, ttft]);
        }
    }
    csv.write(&ctx.csv_path("table4"))?;
    Ok(render_table(
        &["platform", "model", "load time (s)", "TTFT (s)"],
        &rows,
    ))
}

/// Table 5: four TTFT predictors on the four service traces (MAPE/MAE).
pub fn table5(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["trace", "predictor", "mape_pct", "mae_s"]);
    let mut rows = Vec::new();
    for service in ServerProfile::all() {
        // Simulate the collected trace: 1,000 sequential TTFT samples.
        let mut rng = Rng::new(1234);
        let series: Vec<f64> = (0..1000.max(ctx.n_requests))
            .map(|_| service.sample_ttft(&mut rng))
            .collect();
        for mut p in table5_predictors() {
            let e = evaluate(p.as_mut(), &series, series.len() / 2);
            csv.rowd(&[
                service.name.to_string(),
                p.name().to_string(),
                format!("{:.2}", e.mape_pct),
                format!("{:.4}", e.mae),
            ]);
            rows.push(vec![
                service.name.to_string(),
                p.name().to_string(),
                format!("{:.2}", e.mape_pct),
                format!("{:.4}", e.mae),
            ]);
        }
    }
    csv.write(&ctx.csv_path("table5"))?;
    Ok(render_table(
        &["trace", "predictor", "MAPE (%)", "MAE (s)"],
        &rows,
    ))
}

/// Table 6: per-token prefill/decode GFLOPs vs sequence length.
pub fn table6(ctx: &ExpContext) -> anyhow::Result<String> {
    let archs = [
        ModelArch::bloom_1b1(),
        ModelArch::bloom_560m(),
        ModelArch::qwen_0b5(),
    ];
    let mut csv = CsvWriter::new(&["phase", "L", "BLOOM-1.1B", "BLOOM-560M", "Qwen-0.5B"]);
    let mut rows = Vec::new();
    for (phase, f) in [
        ("prefill", true),
        ("decode", false),
    ] {
        for l in [32u32, 64, 128] {
            let vals: Vec<String> = archs
                .iter()
                .map(|a| {
                    let flops = if f {
                        a.prefill_flops_per_token(l)
                    } else {
                        a.decode_flops_per_token(l)
                    };
                    format!("{:.2}", flops / 1e9)
                })
                .collect();
            csv.rowd(&[
                phase.to_string(),
                l.to_string(),
                vals[0].clone(),
                vals[1].clone(),
                vals[2].clone(),
            ]);
            rows.push(vec![
                phase.to_string(),
                format!("L={l}"),
                vals[0].clone(),
                vals[1].clone(),
                vals[2].clone(),
            ]);
        }
    }
    csv.write(&ctx.csv_path("table6"))?;
    Ok(render_table(
        &["phase", "L", "BLOOM-1.1B", "BLOOM-560M", "Qwen-0.5B"],
        &rows,
    ))
}

/// Table 7: FLOPs component ratios at L=128 (decode phase — see
/// cost::flops tests for the calibration note).
pub fn table7(ctx: &ExpContext) -> anyhow::Result<String> {
    let archs = [
        ModelArch::bloom_1b1(),
        ModelArch::bloom_560m(),
        ModelArch::qwen_0b5(),
    ];
    let comps = ["Embedding", "Attention", "FFN", "LayerNorm", "Output"];
    let mut csv = CsvWriter::new(&["component", "BLOOM-1.1B", "BLOOM-560M", "Qwen-0.5B"]);
    let mut rows = Vec::new();
    let ratios: Vec<[f64; 5]> = archs
        .iter()
        .map(|a| a.decode_breakdown(128).ratios_pct())
        .collect();
    for (i, comp) in comps.iter().enumerate() {
        let cells: Vec<String> = ratios.iter().map(|r| format!("{:.2}", r[i])).collect();
        csv.rowd(&[
            comp.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
        rows.push(vec![
            comp.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
    }
    csv.write(&ctx.csv_path("table7"))?;
    Ok(render_table(
        &["component (%)", "BLOOM-1.1B", "BLOOM-560M", "Qwen-0.5B"],
        &rows,
    ))
}

/// Table 8: pricing (static input data, reproduced verbatim).
pub fn table8(ctx: &ExpContext) -> anyhow::Result<String> {
    let mut csv = CsvWriter::new(&["model", "vendor", "input_per_mtok", "output_per_mtok"]);
    let mut rows = Vec::new();
    for p in PRICING_TABLE {
        csv.rowd(&[
            p.model.to_string(),
            p.vendor.to_string(),
            format!("{:.2}", p.input_per_mtok),
            format!("{:.2}", p.output_per_mtok),
        ]);
        rows.push(vec![
            p.model.to_string(),
            p.vendor.to_string(),
            format!("{:.2}", p.input_per_mtok),
            format!("{:.2}", p.output_per_mtok),
        ]);
    }
    csv.write(&ctx.csv_path("table8"))?;
    Ok(render_table(
        &["model", "vendor", "input $/MTok", "output $/MTok"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_tables_run() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_app"),
            n_seeds: 1,
            n_requests: 100,
        };
        assert!(table4(&ctx).unwrap().contains("A40"));
        assert!(table6(&ctx).unwrap().contains("prefill"));
        assert!(table7(&ctx).unwrap().contains("Embedding"));
        assert!(table8(&ctx).unwrap().contains("Anthropic"));
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }

    /// Appendix C's negative result: every predictor ≥ 15% MAPE on every
    /// trace (the paper reports 20.9–53.5%).
    #[test]
    fn table5_predictors_all_inaccurate() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_app5"),
            n_seeds: 1,
            n_requests: 600,
        };
        table5(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.csv_path("table5")).unwrap();
        for line in csv.lines().skip(1) {
            let mape: f64 = line.split(',').nth(2).unwrap().parse().unwrap();
            assert!(mape > 15.0, "predictor too good to be true: {line}");
        }
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
