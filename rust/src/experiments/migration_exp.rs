//! §5.2 migration: Table 3 (delay_num, TBT P99) and Fig 7 (end-to-end
//! cost with vs without migration).

use crate::cost::unified::Constraint;
use crate::experiments::common::*;
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::engine::{Scenario, SimConfig};
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// Table 3: delayed tokens during migration + TBT P99 (migrated requests).
pub fn table3(ctx: &ExpContext) -> anyhow::Result<String> {
    let device = DeviceProfile::pixel7pro_bloom1b1();
    let b = 0.5;
    let mut csv = CsvWriter::new(&[
        "trace",
        "constraint",
        "mean_delay_num",
        "p99_delay_num",
        "tbt_p99",
        "migrated_requests",
    ]);
    let mut rows = Vec::new();
    for service in ServerProfile::all() {
        for constraint in [Constraint::Server, Constraint::Device] {
            let reports = run_cell(
                &service,
                &device,
                constraint,
                disco_for(constraint),
                b,
                true,
                ctx.n_requests,
                ctx.n_seeds,
            );
            let delay_mean = crate::stats::describe::mean(
                &reports.iter().map(|r| r.delay_num_mean).collect::<Vec<_>>(),
            );
            let delay_p99 = crate::stats::describe::mean(
                &reports.iter().map(|r| r.delay_num_p99).collect::<Vec<_>>(),
            );
            let tbt_p99 = crate::stats::describe::mean(
                &reports.iter().map(|r| r.tbt.p99).collect::<Vec<_>>(),
            );
            let migrated: usize =
                reports.iter().map(|r| r.migrated_requests).sum::<usize>() / reports.len();
            csv.rowd(&[
                service.name.to_string(),
                constraint_name(constraint).to_string(),
                format!("{delay_mean:.2}"),
                format!("{delay_p99:.2}"),
                format!("{tbt_p99:.3}"),
                migrated.to_string(),
            ]);
            rows.push(vec![
                service.name.to_string(),
                constraint_name(constraint).to_string(),
                format!("{delay_mean:.2}"),
                format!("{delay_p99:.2}"),
                format!("{tbt_p99:.3}"),
                migrated.to_string(),
            ]);
        }
    }
    csv.write(&ctx.csv_path("table3"))?;
    Ok(render_table(
        &[
            "trace",
            "constraint",
            "mean delay_num",
            "p99 delay_num",
            "TBT p99 (s)",
            "migrated/run",
        ],
        &rows,
    ))
}

/// Fig 7: end-to-end unified cost, DiSCo vs DiSCo-w/o-Migration, across
/// budget ratios under both constraints.
pub fn fig7(ctx: &ExpContext) -> anyhow::Result<String> {
    let device = DeviceProfile::pixel7pro_bloom1b1();
    let mut csv = CsvWriter::new(&[
        "service",
        "constraint",
        "b",
        "cost_with_migration",
        "cost_without_migration",
        "reduction_pct",
    ]);
    let mut rows = Vec::new();
    for service in ServerProfile::all() {
        for constraint in [Constraint::Server, Constraint::Device] {
            let mut best_reduction: f64 = 0.0;
            for &b in &BUDGET_GRID {
                // Costs must be priced by the scenario's own params.
                let scenario = Scenario::new(
                    service.clone(),
                    device.clone(),
                    constraint,
                    SimConfig::default(),
                );
                let kind = disco_for(constraint);
                let with = run_cell(
                    &service, &device, constraint, kind, b, true, ctx.n_requests, ctx.n_seeds,
                );
                let without = run_cell(
                    &service, &device, constraint, kind, b, false, ctx.n_requests, ctx.n_seeds,
                );
                let cw = avg_cost(&with, &scenario.costs);
                let co = avg_cost(&without, &scenario.costs);
                let red = if co > 0.0 { (co - cw) / co * 100.0 } else { 0.0 };
                best_reduction = best_reduction.max(red);
                csv.rowd(&[
                    service.name.to_string(),
                    constraint_name(constraint).to_string(),
                    format!("{b:.1}"),
                    format!("{cw:.6}"),
                    format!("{co:.6}"),
                    format!("{red:.1}"),
                ]);
            }
            rows.push(vec![
                service.name.to_string(),
                constraint_name(constraint).to_string(),
                format!("{best_reduction:.1}%"),
            ]);
        }
    }
    csv.write(&ctx.csv_path("fig7"))?;
    Ok(render_table(
        &["service", "constraint", "max cost reduction from migration"],
        &rows,
    ))
}

/// Helper exposed for the migration_demo example: one request's detailed
/// token timeline with and without migration.
pub fn demo_migration_timeline(seed: u64) -> (crate::metrics::Report, crate::metrics::Report) {
    let service = ServerProfile::deepseek_v25();
    let device = DeviceProfile::pixel7pro_bloom1b1();
    let scenario = Scenario::new(
        service.clone(),
        device.clone(),
        Constraint::Device,
        SimConfig {
            seed,
            ..Default::default()
        },
    );
    let trace = WorkloadSpec::alpaca(200).generate(seed);
    let with = make_policy(
        crate::coordinator::policy::PolicyKind::DiscoD,
        0.6,
        true,
        &scenario,
        &trace,
        seed,
    );
    let without = make_policy(
        crate::coordinator::policy::PolicyKind::DiscoD,
        0.6,
        false,
        &scenario,
        &trace,
        seed,
    );
    (
        scenario.run_report(&trace, &with),
        scenario.run_report(&trace, &without),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_smoke() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_mig"),
            n_seeds: 1,
            n_requests: 120,
        };
        let out = table3(&ctx).unwrap();
        assert!(out.contains("TBT p99"));
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }

    #[test]
    fn demo_timeline_migration_saves_cost() {
        let (with, without) = demo_migration_timeline(5);
        assert!(with.migrated_requests > 0);
        // Same λ for both; compare raw constrained decode usage.
        assert!(with.cost.device_decode_tokens < without.cost.device_decode_tokens);
    }
}
