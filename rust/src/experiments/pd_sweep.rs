//! Prefill/decode disaggregation sweep: pool splits vs the colocated
//! baseline under an explicit KV-transfer cost.
//!
//! Each cell serves the same server-bound workload on the same total
//! shard budget and varies only the fleet shape — colocated (every
//! shard `Unified`) or a P:D split ([`DisaggSpec`]) — crossed with the
//! KV-transfer overhead and the offered rate. Cells at the same seed
//! replay the identical trace and latency draws
//! ([`CellSeed`] content-derived seeding), so TTFT/TBT differences are
//! pure topology + transfer-cost effects: the sweep surfaces both the
//! regime where disaggregation wins tail TTFT (long decode tails pin
//! colocated slots) and the crossover where a slow interconnect hands
//! the TBT win back to the colocated fleet.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::{DisaggSpec, FleetConfig, KvTransferCost};
use crate::trace::generator::{Arrival, WorkloadSpec};
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the P/D-sweep grid.
#[derive(Clone, Copy, Debug)]
pub struct PdCell {
    /// `None` = colocated baseline; `Some((p, d))` = disaggregated.
    pub split: Option<(usize, usize)>,
    /// Fixed per-handoff KV-transfer overhead (seconds; ignored by the
    /// colocated baseline).
    pub transfer_overhead: f64,
    /// Offered arrival rate (req/s).
    pub rate_rps: f64,
}

impl PdCell {
    /// Table/CSV label for the fleet-shape axis.
    pub fn shape_label(&self) -> String {
        match self.split {
            None => "unified".to_string(),
            Some((p, d)) => format!("{p}p{d}d"),
        }
    }
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct PdCellResult {
    pub cell: PdCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tbt: f64,
    /// Prefill→decode handoffs per run.
    pub handoffs: f64,
    /// Total injected KV-transfer seconds per run.
    pub kv_transfer_seconds: f64,
    /// Handoffs that found no admitting decode shard.
    pub handoff_fallbacks: f64,
}

/// Sweep parameters, shared by the `pd-sweep` experiment and the
/// `pd_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct PdSweepParams {
    /// Fleet shapes: `None` = colocated, `Some((p, d))` = disaggregated.
    /// Every shape should provision the same total shard count for a
    /// fair equal-shard-seconds comparison.
    pub splits: Vec<Option<(usize, usize)>>,
    /// Per-handoff fixed overheads (seconds) to cross the splits with.
    pub transfer_overheads: Vec<f64>,
    /// Seconds of KV transfer per prompt token.
    pub transfer_per_token: f64,
    pub rates: Vec<f64>,
    /// Total shard count of the colocated baseline.
    pub shards: usize,
    pub slots_per_shard: usize,
    pub balancer: BalancerKind,
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for PdSweepParams {
    fn default() -> Self {
        PdSweepParams {
            splits: vec![None, Some((2, 2)), Some((3, 1)), Some((1, 3))],
            // NVLink-class vs a pathologically slow interconnect: the
            // second cell exists to show the crossover, not a plausible
            // deployment.
            transfer_overheads: vec![0.005, 1.0],
            transfer_per_token: 2e-6,
            // DeepSeek prefill ≈ 1.3 s, tail ≈ 3 s ⇒ a 4×1-slot
            // colocated fleet saturates near 0.9 rps; 1.2 rps overloads
            // it while a 2-shard prefill pool (≈ 1.5 rps) keeps up.
            rates: vec![0.6, 1.2],
            shards: 4,
            slots_per_shard: 1,
            balancer: BalancerKind::LeastWork,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_requests: 200,
            n_seeds: 3,
            service: ServerProfile::deepseek_v25(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

impl PdSweepParams {
    /// Number of grid cells: the colocated baseline runs once per rate
    /// (the transfer cost cannot touch it), each split once per
    /// (overhead × rate).
    pub fn n_cells(&self) -> usize {
        let splits = self.splits.iter().filter(|s| s.is_some()).count();
        let unified = self.splits.iter().filter(|s| s.is_none()).count();
        self.rates.len() * (unified + splits * self.transfer_overheads.len())
    }
}

/// Run the (rate × shape × transfer-cost) grid in parallel; cells come
/// back in grid order (rates outer, shapes middle, overheads inner —
/// the colocated baseline collapses its overhead axis).
pub fn run_grid(params: &PdSweepParams) -> Vec<PdCellResult> {
    let mut cells = Vec::with_capacity(params.n_cells());
    for &rate_rps in &params.rates {
        for &split in &params.splits {
            match split {
                None => cells.push(PdCell {
                    split,
                    transfer_overhead: 0.0,
                    rate_rps,
                }),
                Some(_) => {
                    for &transfer_overhead in &params.transfer_overheads {
                        cells.push(PdCell {
                            split,
                            transfer_overhead,
                            rate_rps,
                        });
                    }
                }
            }
        }
    }
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &PdSweepParams, cell: &PdCell) -> PdCellResult {
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut mean_tbt = Vec::new();
    let mut handoffs = Vec::new();
    let mut transfer = Vec::new();
    let mut fallbacks = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seed over the rate only: every shape and
        // transfer cost at the same (seed, rate) replays the identical
        // trace and latency draws (paired comparison).
        let cell_seed = CellSeed::new(seed).mix_f64(cell.rate_rps);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Server,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed {
                gap: 1.0 / cell.rate_rps,
            },
            ..WorkloadSpec::alpaca(params.n_requests)
        };
        let trace = spec.generate(cell_seed.trace(0x9D5EE9));
        let mut fleet =
            FleetConfig::sharded(params.shards, params.slots_per_shard, params.balancer);
        if let Some((p, d)) = cell.split {
            fleet = fleet.with_disagg(DisaggSpec {
                transfer: KvTransferCost {
                    per_token: params.transfer_per_token,
                    overhead: cell.transfer_overhead,
                },
                ..DisaggSpec::split(p, d)
            });
        }
        let policy = make_policy(
            params.policy,
            params.b,
            false,
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let rep = scenario.run_fleet_report(&trace, &policy, &fleet);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        mean_tbt.push(rep.qoe.tbt.mean);
        handoffs.push(rep.load.handoff_count as f64);
        transfer.push(rep.load.kv_transfer_seconds);
        fallbacks.push(rep.load.handoff_fallbacks as f64);
    }
    let avg = crate::stats::describe::mean;
    PdCellResult {
        cell: *cell,
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        mean_tbt: avg(&mean_tbt),
        handoffs: avg(&handoffs),
        kv_transfer_seconds: avg(&transfer),
        handoff_fallbacks: avg(&fallbacks),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[PdCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.cell.shape_label(),
                format!("{:.3}", r.cell.transfer_overhead),
                format!("{:.2}", r.cell.rate_rps),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.4}", r.mean_tbt),
                format!("{:.1}", r.handoffs),
                format!("{:.2}", r.kv_transfer_seconds),
                format!("{:.1}", r.handoff_fallbacks),
            ]
        })
        .collect();
    render_table(
        &[
            "shape",
            "xfer@",
            "rate",
            "mean TTFT",
            "p99 TTFT",
            "mean TBT",
            "handoffs",
            "xfer s",
            "fallbacks",
        ],
        &rows,
    )
}

/// The `pd-sweep` experiment entry: default grid, CSV + table.
pub fn pd_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = PdSweepParams {
        n_requests: ctx.n_requests.clamp(50, 200),
        n_seeds: ctx.n_seeds.clamp(1, 3),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "shape",
        "transfer_overhead",
        "rate_rps",
        "mean_ttft",
        "p99_ttft",
        "mean_tbt",
        "handoffs",
        "kv_transfer_seconds",
        "handoff_fallbacks",
    ]);
    for r in &results {
        csv.rowd(&[
            r.cell.shape_label(),
            format!("{:.4}", r.cell.transfer_overhead),
            format!("{:.3}", r.cell.rate_rps),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.5}", r.mean_tbt),
            format!("{:.2}", r.handoffs),
            format!("{:.3}", r.kv_transfer_seconds),
            format!("{:.2}", r.handoff_fallbacks),
        ]);
    }
    csv.write(&ctx.csv_path("pd-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> PdSweepParams {
        PdSweepParams {
            splits: vec![None, Some((2, 2))],
            transfer_overheads: vec![0.005],
            rates: vec![1.2],
            n_requests: 80,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_pairs_unified_against_split_and_counts_handoffs() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), params.n_cells());
        assert_eq!(results.len(), 2);
        let (unified, split) = (&results[0], &results[1]);
        assert!(unified.cell.split.is_none());
        assert_eq!(unified.handoffs, 0.0, "colocated cells must not hand off");
        assert_eq!(unified.kv_transfer_seconds, 0.0);
        assert!(split.handoffs > 0.0, "split cells must hand off");
        assert!(split.kv_transfer_seconds > 0.0);
        assert_eq!(split.handoff_fallbacks, 0.0, "static decode pool always admits");
        // The acceptance overload: long decode tails pin colocated
        // slots, so the split wins tail TTFT on the same shard budget.
        assert!(
            split.p99_ttft < unified.p99_ttft,
            "2p2d must beat unified p99 TTFT at 1.2 rps: {:.2} vs {:.2}",
            split.p99_ttft,
            unified.p99_ttft
        );
    }

    #[test]
    fn slow_interconnect_loses_the_tbt_comparison() {
        let params = PdSweepParams {
            splits: vec![None, Some((2, 2))],
            transfer_overheads: vec![1.0],
            rates: vec![0.6],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        };
        let results = run_grid(&params);
        let (unified, split) = (&results[0], &results[1]);
        assert!(
            split.mean_tbt > unified.mean_tbt,
            "a 1 s/handoff interconnect must lose mean TBT: {:.4} vs {:.4}",
            split.mean_tbt,
            unified.mean_tbt
        );
    }

    #[test]
    fn pd_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_pd_sweep"),
            n_seeds: 1,
            n_requests: 60,
        };
        let out = pd_sweep(&ctx).unwrap();
        assert!(out.contains("shape"));
        let csv = std::fs::read_to_string(ctx.csv_path("pd-sweep")).unwrap();
        // Header + 2 rates × (1 unified + 3 splits × 2 overheads).
        assert_eq!(csv.lines().count(), 1 + 14);
        assert_eq!(PdSweepParams::default().n_cells(), 14);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
