//! Experiment registry: one runner per table/figure of the paper.
//!
//! `disco exp <id>` (or `all`) regenerates the artifact: each runner
//! prints the same rows/series the paper reports and writes
//! `results/<id>.csv`. See DESIGN.md's experiment index for the mapping.

pub mod ablation;
pub mod ablations2;
pub mod appendix;
pub mod autoscale_sweep;
pub mod batching_sweep;
pub mod characterization;
pub mod common;
pub mod endtoend;
pub mod failover_sweep;
pub mod kv_sweep;
pub mod load_sweep;
pub mod migration_exp;
pub mod pd_sweep;
pub mod quality_exp;
pub mod shard_sweep;
pub mod zone_sweep;

use std::path::PathBuf;

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpContext {
    /// Output directory for CSVs (default `results/`).
    pub out_dir: PathBuf,
    /// Number of seeds to average over (the paper uses 10 runs).
    pub n_seeds: u64,
    /// Requests per trace (the paper samples 1,000).
    pub n_requests: usize,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            out_dir: PathBuf::from("results"),
            n_seeds: 10,
            n_requests: 1000,
        }
    }
}

impl ExpContext {
    /// Reduced-cost context for CI / smoke runs.
    pub fn quick() -> Self {
        ExpContext {
            out_dir: PathBuf::from("results"),
            n_seeds: 3,
            n_requests: 300,
        }
    }

    pub fn csv_path(&self, id: &str) -> PathBuf {
        self.out_dir.join(format!("{id}.csv"))
    }
}

/// An experiment runner entry.
pub struct ExperimentDef {
    pub id: &'static str,
    pub title: &'static str,
    pub run: fn(&ExpContext) -> anyhow::Result<String>,
}

/// All experiments, in paper order.
pub fn registry() -> Vec<ExperimentDef> {
    vec![
        ExperimentDef {
            id: "fig2",
            title: "Fig 2: on-device TTFT stability vs on-server spikes",
            run: characterization::fig2,
        },
        ExperimentDef {
            id: "table1",
            title: "Table 1: Pearson(prompt length, TTFT) per deployment",
            run: characterization::table1,
        },
        ExperimentDef {
            id: "fig3",
            title: "Fig 3: TBT stability across setups",
            run: characterization::fig3,
        },
        ExperimentDef {
            id: "fig6",
            title: "Fig 6: mean TTFT vs budget ratio (4 traces)",
            run: endtoend::fig6,
        },
        ExperimentDef {
            id: "table2",
            title: "Table 2: tail TTFT reduction vs stochastic dispatching",
            run: endtoend::table2,
        },
        ExperimentDef {
            id: "table3",
            title: "Table 3: migration delay_num + TBT P99",
            run: migration_exp::table3,
        },
        ExperimentDef {
            id: "fig7",
            title: "Fig 7: end-to-end cost with/without migration",
            run: migration_exp::fig7,
        },
        ExperimentDef {
            id: "fig5",
            title: "Fig 5: mean TTFT reduction on DiffusionDB activity levels",
            run: ablation::fig5,
        },
        ExperimentDef {
            id: "fig8",
            title: "Fig 8: response quality across migration points",
            run: quality_exp::fig8,
        },
        ExperimentDef {
            id: "fig9",
            title: "Fig 9: scheduler overhead scalability",
            run: ablation::fig9,
        },
        ExperimentDef {
            id: "fig10",
            title: "Fig 10: quality bounds (translation + instruct)",
            run: quality_exp::fig10,
        },
        ExperimentDef {
            id: "table4",
            title: "Table 4: cold-start load time vs TTFT",
            run: appendix::table4,
        },
        ExperimentDef {
            id: "table5",
            title: "Table 5: TTFT predictor accuracy (MAPE/MAE)",
            run: appendix::table5,
        },
        ExperimentDef {
            id: "table6",
            title: "Table 6: prefill/decode FLOPs per token",
            run: appendix::table6,
        },
        ExperimentDef {
            id: "table7",
            title: "Table 7: FLOPs component ratios at L=128",
            run: appendix::table7,
        },
        ExperimentDef {
            id: "table8",
            title: "Table 8: LLM service pricing",
            run: appendix::table8,
        },
        ExperimentDef {
            id: "load-sweep",
            title: "Fleet: TTFT/queue-delay vs arrival rate under server admission limits",
            run: load_sweep::load_sweep,
        },
        ExperimentDef {
            id: "shard-sweep",
            title: "Fleet: balancer comparison across shard counts and arrival rates",
            run: shard_sweep::shard_sweep,
        },
        ExperimentDef {
            id: "autoscale-sweep",
            title: "Fleet: autoscaling policies vs static provisioning under bursty load",
            run: autoscale_sweep::autoscale_sweep,
        },
        ExperimentDef {
            id: "failover-sweep",
            title: "Fleet: migration targeting under mid-burst shard failure",
            run: failover_sweep::failover_sweep,
        },
        ExperimentDef {
            id: "batching-sweep",
            title: "Fleet: continuous batching vs slot admission across token budgets",
            run: batching_sweep::batching_sweep,
        },
        ExperimentDef {
            id: "kv-sweep",
            title: "Fleet: paged KV pools × prefix caching across session loads",
            run: kv_sweep::kv_sweep,
        },
        ExperimentDef {
            id: "pd-sweep",
            title: "Fleet: prefill/decode disaggregation vs colocated under KV-transfer cost",
            run: pd_sweep::pd_sweep,
        },
        ExperimentDef {
            id: "zone-sweep",
            title: "Fleet: zone-partitioned cells across cores (Z × shards × rate)",
            run: zone_sweep::zone_sweep,
        },
        ExperimentDef {
            id: "abl-alpha",
            title: "Ablation: tail-protection reservation α (§4.2 Phase 1)",
            run: ablations2::abl_alpha,
        },
        ExperimentDef {
            id: "abl-buffer",
            title: "Ablation: Eq. 5 token-buffer sizing",
            run: ablations2::abl_buffer,
        },
        ExperimentDef {
            id: "abl-rc",
            title: "Ablation: consumption-rate sensitivity",
            run: ablations2::abl_rc,
        },
        ExperimentDef {
            id: "abl-smooth",
            title: "Ablation: Algorithm-2 stepwise vs Eq. 1–2 smooth waits",
            run: ablations2::abl_smooth,
        },
    ]
}

/// Run one experiment by id (or "all"); returns rendered output.
pub fn run(id: &str, ctx: &ExpContext) -> anyhow::Result<String> {
    std::fs::create_dir_all(&ctx.out_dir)?;
    if id == "all" {
        let mut out = String::new();
        for def in registry() {
            log::info!("running {} — {}", def.id, def.title);
            out.push_str(&format!("\n=== {} — {} ===\n", def.id, def.title));
            out.push_str(&(def.run)(ctx)?);
        }
        return Ok(out);
    }
    let def = registry()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (see `disco list`)"))?;
    (def.run)(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_complete() {
        let defs = registry();
        let ids: std::collections::BTreeSet<&str> = defs.iter().map(|d| d.id).collect();
        assert_eq!(ids.len(), defs.len());
        for required in [
            "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "table1",
            "table2", "table3", "table4", "table5", "table6", "table7", "table8",
        ] {
            assert!(ids.contains(required), "missing {required}");
        }
    }

    #[test]
    fn unknown_id_errors() {
        let ctx = ExpContext::quick();
        assert!(run("nope", &ctx).is_err());
    }
}
