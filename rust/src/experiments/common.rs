//! Shared sweep machinery for the evaluation experiments, including the
//! scoped-thread parallel runner ([`par_map`]) that fans grid cells out
//! across cores. Determinism is preserved by construction: each cell
//! carries its own seed (derived through `util::rng`-style mixing, never
//! from thread identity) and results land by input index.

use crate::coordinator::policy::{Policy, PolicyKind};
use crate::cost::unified::Constraint;
use crate::metrics::Report;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::engine::{Scenario, SimConfig};
use crate::trace::generator::WorkloadSpec;
use crate::trace::Trace;

// The scoped-thread runner lives in `util::par` (it now also powers
// within-cell zone parallelism, `sim/zones.rs`); re-exported here so
// sweep code keeps its historical import path.
pub use crate::util::par::{par_map, worker_threads};

/// The budget-ratio grid the sweeps use ("across the whole cost budget
/// range", Table 2).
pub const BUDGET_GRID: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Content-derived deterministic seeding for sweep cells.
///
/// Every grid cell folds its *content* (arrival rate, shard count, …)
/// into a base seed — never its grid position or worker thread — so the
/// same cell reproduces identical numbers no matter which other cells
/// share the grid, and cells differing only in policy/balancer run
/// against the same trace (paired comparisons, not unpaired variance).
#[derive(Clone, Copy, Debug)]
pub struct CellSeed(u64);

impl CellSeed {
    pub fn new(seed: u64) -> CellSeed {
        CellSeed(seed)
    }

    /// Fold an integer axis (shard count, balancer index, …) into the
    /// seed.
    pub fn mix_u64(self, x: u64) -> CellSeed {
        CellSeed(self.0 ^ x.rotate_left(17).wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fold a float axis (arrival rate, budget ratio, …) into the seed.
    pub fn mix_f64(self, x: f64) -> CellSeed {
        self.mix_u64(x.to_bits())
    }

    /// Seed for the scenario (latency sampling streams).
    pub fn scenario(self) -> u64 {
        self.0
    }

    /// Seed for the trace generator, decorrelated from the scenario
    /// stream by a caller-chosen tag (each sweep family keeps its own
    /// tag so historical numbers stay bit-stable).
    pub fn trace(self, tag: u64) -> u64 {
        self.0 ^ tag
    }
}

/// Build a policy (planning DiSCo variants from profiled distributions).
pub fn make_policy(
    kind: PolicyKind,
    b: f64,
    migration: bool,
    scenario: &Scenario,
    trace: &Trace,
    seed: u64,
) -> Policy {
    match kind {
        PolicyKind::DiscoS | PolicyKind::DiscoD | PolicyKind::DiscoDSmooth => {
            let ecdf = scenario.profile_server_ttft(2000, seed);
            Policy::plan(kind, b, migration, &ecdf, &trace.prompt_lens())
        }
        _ => Policy::simple(kind, b, migration),
    }
}

/// Run one (service, device, constraint, policy, b) cell over several
/// seeds — in parallel, one worker per seed; returns the per-seed
/// reports in seed order.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    service: &ServerProfile,
    device: &DeviceProfile,
    constraint: Constraint,
    kind: PolicyKind,
    b: f64,
    migration: bool,
    n_requests: usize,
    n_seeds: u64,
) -> Vec<Report> {
    let seeds: Vec<u64> = (0..n_seeds).collect();
    par_map(&seeds, |_, &seed| {
        let cell = CellSeed::new(seed);
        let scenario = Scenario::new(
            service.clone(),
            device.clone(),
            constraint,
            SimConfig {
                seed: cell.scenario(),
                ..Default::default()
            },
        );
        let trace = WorkloadSpec::alpaca(n_requests).generate(cell.trace(0xA1FA));
        let policy = make_policy(kind, b, migration, &scenario, &trace, seed);
        scenario.run_report(&trace, &policy)
    })
}

/// Seed-averaged mean TTFT.
pub fn avg_mean_ttft(reports: &[Report]) -> f64 {
    crate::stats::describe::mean(&reports.iter().map(|r| r.ttft.mean).collect::<Vec<_>>())
}

/// Seed-averaged P99 TTFT.
pub fn avg_p99_ttft(reports: &[Report]) -> f64 {
    crate::stats::describe::mean(&reports.iter().map(|r| r.ttft.p99).collect::<Vec<_>>())
}

/// Seed-averaged total cost.
pub fn avg_cost(reports: &[Report], scenario_costs: &crate::cost::unified::CostParams) -> f64 {
    crate::stats::describe::mean(
        &reports
            .iter()
            .map(|r| r.total_cost(scenario_costs))
            .collect::<Vec<_>>(),
    )
}

/// The stochastic baseline matching a constraint.
pub fn stoch_for(constraint: Constraint) -> PolicyKind {
    match constraint {
        Constraint::Server => PolicyKind::StochS,
        Constraint::Device => PolicyKind::StochD,
    }
}

/// The DiSCo policy matching a constraint.
pub fn disco_for(constraint: Constraint) -> PolicyKind {
    match constraint {
        Constraint::Server => PolicyKind::DiscoS,
        Constraint::Device => PolicyKind::DiscoD,
    }
}

/// Display name for a constraint.
pub fn constraint_name(c: Constraint) -> &'static str {
    match c {
        Constraint::Server => "Server",
        Constraint::Device => "Device",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cell_produces_seeded_reports() {
        let reports = run_cell(
            &ServerProfile::command(),
            &DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            PolicyKind::StochS,
            0.5,
            false,
            100,
            2,
        );
        assert_eq!(reports.len(), 2);
        assert!(avg_mean_ttft(&reports) > 0.0);
        assert!(avg_p99_ttft(&reports) >= avg_mean_ttft(&reports));
    }

    #[test]
    fn par_map_preserves_order_and_results() {
        let items: Vec<u64> = (0..257).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x * x + 1).collect();
        let parallel = par_map(&items, |i, &x| {
            assert_eq!(i as u64, x);
            x * x + 1
        });
        assert_eq!(parallel, serial);
        // Empty and single-item inputs pass through.
        assert_eq!(par_map::<u64, u64, _>(&[], |_, &x| x), Vec::<u64>::new());
        assert_eq!(par_map(&[7u64], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_matches_serial_simulation() {
        // A sweep computed in parallel must be bit-identical to the same
        // sweep computed serially (per-cell seeding, no shared state).
        let reports = run_cell(
            &ServerProfile::gpt4o_mini(),
            &DeviceProfile::pixel7pro_bloom560m(),
            Constraint::Server,
            PolicyKind::StochS,
            0.5,
            false,
            80,
            4,
        );
        for (seed, r) in reports.iter().enumerate() {
            let scenario = Scenario::new(
                ServerProfile::gpt4o_mini(),
                DeviceProfile::pixel7pro_bloom560m(),
                Constraint::Server,
                SimConfig {
                    seed: seed as u64,
                    ..Default::default()
                },
            );
            let trace = WorkloadSpec::alpaca(80).generate(seed as u64 ^ 0xA1FA);
            let policy = make_policy(
                PolicyKind::StochS,
                0.5,
                false,
                &scenario,
                &trace,
                seed as u64,
            );
            let serial = scenario.run_report(&trace, &policy);
            assert_eq!(r.ttft.mean.to_bits(), serial.ttft.mean.to_bits());
            assert_eq!(r.ttft.p99.to_bits(), serial.ttft.p99.to_bits());
        }
    }

    #[test]
    fn cell_seed_is_content_derived_and_order_free() {
        // Bit-compatible with the historical load-sweep formula.
        let legacy = 3u64
            ^ 0.5f64
                .to_bits()
                .rotate_left(17)
                .wrapping_mul(0x9E3779B97F4A7C15);
        assert_eq!(CellSeed::new(3).mix_f64(0.5).scenario(), legacy);
        assert_eq!(CellSeed::new(3).mix_f64(0.5).trace(0xF1EE7), legacy ^ 0xF1EE7);
        // Mixing is order-independent (XOR-fold), so axis order can't
        // silently change a cell's numbers.
        let a = CellSeed::new(7).mix_f64(2.0).mix_u64(4).scenario();
        let b = CellSeed::new(7).mix_u64(4).mix_f64(2.0).scenario();
        assert_eq!(a, b);
        // Different content ⇒ different seeds.
        assert_ne!(
            CellSeed::new(7).mix_f64(2.0).scenario(),
            CellSeed::new(7).mix_f64(4.0).scenario()
        );
        assert_ne!(
            CellSeed::new(7).mix_u64(2).scenario(),
            CellSeed::new(7).mix_u64(8).scenario()
        );
    }

    #[test]
    fn helpers_map_constraints() {
        assert_eq!(stoch_for(Constraint::Server), PolicyKind::StochS);
        assert_eq!(disco_for(Constraint::Device), PolicyKind::DiscoD);
        assert_eq!(constraint_name(Constraint::Server), "Server");
    }
}
