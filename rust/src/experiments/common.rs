//! Shared sweep machinery for the evaluation experiments.

use crate::coordinator::policy::{Policy, PolicyKind};
use crate::cost::unified::Constraint;
use crate::metrics::Report;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::engine::{Scenario, SimConfig};
use crate::trace::generator::WorkloadSpec;
use crate::trace::Trace;

/// The budget-ratio grid the sweeps use ("across the whole cost budget
/// range", Table 2).
pub const BUDGET_GRID: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

/// Build a policy (planning DiSCo variants from profiled distributions).
pub fn make_policy(
    kind: PolicyKind,
    b: f64,
    migration: bool,
    scenario: &Scenario,
    trace: &Trace,
    seed: u64,
) -> Policy {
    match kind {
        PolicyKind::DiscoS | PolicyKind::DiscoD | PolicyKind::DiscoDSmooth => {
            let ecdf = scenario.profile_server_ttft(2000, seed);
            Policy::plan(kind, b, migration, &ecdf, &trace.prompt_lens())
        }
        _ => Policy::simple(kind, b, migration),
    }
}

/// Run one (service, device, constraint, policy, b) cell over several
/// seeds; returns the per-seed reports.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    service: &ServerProfile,
    device: &DeviceProfile,
    constraint: Constraint,
    kind: PolicyKind,
    b: f64,
    migration: bool,
    n_requests: usize,
    n_seeds: u64,
) -> Vec<Report> {
    (0..n_seeds)
        .map(|seed| {
            let scenario = Scenario::new(
                service.clone(),
                device.clone(),
                constraint,
                SimConfig {
                    seed,
                    ..Default::default()
                },
            );
            let trace = WorkloadSpec::alpaca(n_requests).generate(seed ^ 0xA1FA);
            let policy = make_policy(kind, b, migration, &scenario, &trace, seed);
            scenario.run_report(&trace, &policy)
        })
        .collect()
}

/// Seed-averaged mean TTFT.
pub fn avg_mean_ttft(reports: &[Report]) -> f64 {
    crate::stats::describe::mean(&reports.iter().map(|r| r.ttft.mean).collect::<Vec<_>>())
}

/// Seed-averaged P99 TTFT.
pub fn avg_p99_ttft(reports: &[Report]) -> f64 {
    crate::stats::describe::mean(&reports.iter().map(|r| r.ttft.p99).collect::<Vec<_>>())
}

/// Seed-averaged total cost.
pub fn avg_cost(reports: &[Report], scenario_costs: &crate::cost::unified::CostParams) -> f64 {
    crate::stats::describe::mean(
        &reports
            .iter()
            .map(|r| r.total_cost(scenario_costs))
            .collect::<Vec<_>>(),
    )
}

/// The stochastic baseline matching a constraint.
pub fn stoch_for(constraint: Constraint) -> PolicyKind {
    match constraint {
        Constraint::Server => PolicyKind::StochS,
        Constraint::Device => PolicyKind::StochD,
    }
}

/// The DiSCo policy matching a constraint.
pub fn disco_for(constraint: Constraint) -> PolicyKind {
    match constraint {
        Constraint::Server => PolicyKind::DiscoS,
        Constraint::Device => PolicyKind::DiscoD,
    }
}

/// Display name for a constraint.
pub fn constraint_name(c: Constraint) -> &'static str {
    match c {
        Constraint::Server => "Server",
        Constraint::Device => "Device",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cell_produces_seeded_reports() {
        let reports = run_cell(
            &ServerProfile::command(),
            &DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            PolicyKind::StochS,
            0.5,
            false,
            100,
            2,
        );
        assert_eq!(reports.len(), 2);
        assert!(avg_mean_ttft(&reports) > 0.0);
        assert!(avg_p99_ttft(&reports) >= avg_mean_ttft(&reports));
    }

    #[test]
    fn helpers_map_constraints() {
        assert_eq!(stoch_for(Constraint::Server), PolicyKind::StochS);
        assert_eq!(disco_for(Constraint::Device), PolicyKind::DiscoD);
        assert_eq!(constraint_name(Constraint::Server), "Server");
    }
}
