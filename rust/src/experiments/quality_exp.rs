//! Appendix D / Fig 8 / Fig 10: response quality across migration points.

use crate::experiments::ExpContext;
use crate::quality::{judge_score, judges, qwen, rouge_score};
use crate::util::csv::CsvWriter;
use crate::util::render_table;
use crate::util::rng::Rng;

/// The paper's migration sweep: max sequence length processed by the
/// first endpoint before handing off (Appendix D.2).
pub const FIRST_LENS: [u32; 5] = [0, 4, 16, 64, 256];
pub const TOTAL_LEN: u32 = 256;

/// The four model-pair configurations (first → second endpoint).
pub fn model_pairs() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("0.5B-7B", 0.5, 7.0),
        ("3B-7B", 3.0, 7.0),
        ("7B-0.5B", 7.0, 0.5),
        ("7B-3B", 7.0, 3.0),
    ]
}

/// Fig 8: judge scores flat across migration points, bounded by Eq. 6.
pub fn fig8(ctx: &ExpContext) -> anyhow::Result<String> {
    let n_items = 500usize; // paper: 500 Alpaca items
    let mut csv = CsvWriter::new(&["pair", "judge", "first_len", "mean_score"]);
    let mut rows = Vec::new();
    let mut rng = Rng::new(88);
    for (pair, a_size, b_size) in model_pairs() {
        let qa = qwen(a_size).instruct_score;
        let qb = qwen(b_size).instruct_score;
        for judge in judges() {
            let mut cells = vec![pair.to_string(), judge.name.to_string()];
            for &fl in &FIRST_LENS {
                let scores: Vec<f64> = (0..n_items)
                    .map(|_| judge_score(&judge, qa, qb, fl, TOTAL_LEN, &mut rng))
                    .collect();
                let mean = crate::stats::describe::mean(&scores);
                csv.rowd(&[
                    pair.to_string(),
                    judge.name.to_string(),
                    fl.to_string(),
                    format!("{mean:.3}"),
                ]);
                cells.push(format!("{mean:.2}"));
            }
            rows.push(cells);
        }
    }
    csv.write(&ctx.csv_path("fig8"))?;
    Ok(render_table(
        &["pair", "judge", "L=0", "L=4", "L=16", "L=64", "L=256"],
        &rows,
    ))
}

/// Fig 10: translation ROUGE-1 band (0.23–0.26) + Eq. 6 bound check.
pub fn fig10(ctx: &ExpContext) -> anyhow::Result<String> {
    let n_items = 500usize; // paper: 500 Flores items
    let mut csv = CsvWriter::new(&["pair", "first_len", "mean_rouge1", "min_q", "max_q"]);
    let mut rows = Vec::new();
    let mut rng = Rng::new(77);
    for (pair, a_size, b_size) in model_pairs() {
        let qa = qwen(a_size);
        let qb = qwen(b_size);
        let mut cells = vec![pair.to_string()];
        for &fl in &FIRST_LENS {
            let scores: Vec<f64> = (0..n_items)
                .map(|_| rouge_score(&qa, &qb, fl, TOTAL_LEN, &mut rng))
                .collect();
            let mean = crate::stats::describe::mean(&scores);
            csv.rowd(&[
                pair.to_string(),
                fl.to_string(),
                format!("{mean:.4}"),
                format!("{:.4}", qa.rouge1.min(qb.rouge1)),
                format!("{:.4}", qa.rouge1.max(qb.rouge1)),
            ]);
            cells.push(format!("{mean:.3}"));
        }
        rows.push(cells);
    }
    csv.write(&ctx.csv_path("fig10"))?;
    Ok(render_table(
        &["pair", "L=0", "L=4", "L=16", "L=64", "L=256"],
        &rows,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_scores_in_paper_band() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_q"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = fig8(&ctx).unwrap();
        assert!(out.contains("0.5B-7B"));
        // Appendix D: "scores show consistent ranges from 4 to 6" — check
        // the CSV means stay in a slightly padded band (judge bias/noise).
        let csv = std::fs::read_to_string(ctx.csv_path("fig8")).unwrap();
        for line in csv.lines().skip(1) {
            let mean: f64 = line.split(',').nth(3).unwrap().parse().unwrap();
            assert!((3.5..=6.5).contains(&mean), "line {line}");
        }
        let f10 = fig10(&ctx).unwrap();
        assert!(f10.contains("7B-3B"));
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
