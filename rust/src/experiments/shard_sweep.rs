//! Shard sweep: balancer comparison across the (shard count × balancer ×
//! arrival rate) grid of the sharded fleet simulator.
//!
//! Each cell fixes a fleet topology (K shards, `slots_per_shard`
//! admissions each) and a [`BalancerKind`], then replays a Poisson
//! workload at the target aggregate rate. Balancers at the same
//! (K, rate, seed) see the *same* trace and the same pre-drawn latency
//! samples — the balancer RNG stream is disjoint from per-request
//! streams — so differences in queue delay and tail TTFT are pure
//! balancing effects, paired cell-for-cell. Cells fan out across cores
//! via [`crate::experiments::common::par_map`] with [`CellSeed`]
//! content-derived seeding, so results are bit-reproducible and
//! grid-shape independent.

use crate::coordinator::policy::PolicyKind;
use crate::cost::unified::Constraint;
use crate::experiments::common::{make_policy, par_map, CellSeed};
use crate::experiments::ExpContext;
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::balancer::BalancerKind;
use crate::sim::engine::{Scenario, SimConfig};
use crate::sim::fleet::FleetConfig;
use crate::trace::generator::WorkloadSpec;
use crate::util::csv::CsvWriter;
use crate::util::render_table;

/// One cell of the shard-sweep grid.
#[derive(Clone, Debug)]
pub struct ShardCell {
    pub shards: usize,
    pub balancer: BalancerKind,
    pub rate_rps: f64,
}

/// Seed-averaged results for one cell.
#[derive(Clone, Debug)]
pub struct ShardCellResult {
    pub cell: ShardCell,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_queue_delay: f64,
    pub p99_queue_delay: f64,
    pub server_utilization: f64,
    /// Max/mean shard utilization (1.0 = perfectly balanced; 0.0 when
    /// undefined, i.e. a single shard).
    pub imbalance: f64,
}

/// Sweep parameters, shared by the `shard-sweep` experiment and the
/// `shard_sweep` CLI subcommand.
#[derive(Clone, Debug)]
pub struct ShardSweepParams {
    pub shard_counts: Vec<usize>,
    pub balancers: Vec<BalancerKind>,
    pub rates: Vec<f64>,
    /// Concurrent admissions per shard.
    pub slots_per_shard: usize,
    /// Dispatch policy every cell runs (the balancer is the axis under
    /// study; ServerOnly isolates it from device-race effects).
    pub policy: PolicyKind,
    pub b: f64,
    pub n_requests: usize,
    pub n_seeds: u64,
    pub service: ServerProfile,
    pub device: DeviceProfile,
}

impl Default for ShardSweepParams {
    fn default() -> Self {
        ShardSweepParams {
            shard_counts: vec![1, 2, 4, 8],
            balancers: BalancerKind::all(),
            // From comfortably underloaded to past saturation for the
            // default K=4 × GPT profile (service ≈ 1.3 s ⇒ capacity ≈
            // K/1.3 rps per slot).
            rates: vec![0.5, 2.0, 4.0],
            slots_per_shard: 1,
            policy: PolicyKind::ServerOnly,
            b: 1.0,
            n_requests: 400,
            n_seeds: 3,
            service: ServerProfile::gpt4o_mini(),
            device: DeviceProfile::xiaomi14_qwen0b5(),
        }
    }
}

/// Run the (K × balancer × rate) grid in parallel; cells come back in
/// grid order (shard counts outer, balancers middle, rates inner).
pub fn run_grid(params: &ShardSweepParams) -> Vec<ShardCellResult> {
    let cells: Vec<ShardCell> = params
        .shard_counts
        .iter()
        .flat_map(|&shards| {
            params.balancers.iter().flat_map(move |&balancer| {
                params.rates.iter().map(move |&rate_rps| ShardCell {
                    shards,
                    balancer,
                    rate_rps,
                })
            })
        })
        .collect();
    par_map(&cells, |_, cell| run_cell(params, cell))
}

fn run_cell(params: &ShardSweepParams, cell: &ShardCell) -> ShardCellResult {
    let fleet = FleetConfig::sharded(cell.shards, params.slots_per_shard, cell.balancer);
    let mut mean_ttft = Vec::new();
    let mut p99_ttft = Vec::new();
    let mut qd_mean = Vec::new();
    let mut qd_p99 = Vec::new();
    let mut util = Vec::new();
    let mut imb = Vec::new();
    for seed in 0..params.n_seeds {
        // Content-derived seed over (rate, K) — deliberately NOT over the
        // balancer, so every balancer at a (K, rate, seed) cell replays
        // the identical trace and latency draws (paired comparison).
        let cell_seed = CellSeed::new(seed)
            .mix_f64(cell.rate_rps)
            .mix_u64(cell.shards as u64);
        let scenario = Scenario::new(
            params.service.clone(),
            params.device.clone(),
            Constraint::Server,
            SimConfig {
                seed: cell_seed.scenario(),
                ..Default::default()
            },
        );
        let trace = WorkloadSpec::alpaca(params.n_requests)
            .at_rate(cell.rate_rps)
            .generate(cell_seed.trace(0x5AA4D));
        let policy = make_policy(
            params.policy,
            params.b,
            false,
            &scenario,
            &trace,
            cell_seed.scenario(),
        );
        let rep = scenario.run_fleet_report(&trace, &policy, &fleet);
        mean_ttft.push(rep.qoe.ttft.mean);
        p99_ttft.push(rep.qoe.ttft.p99);
        qd_mean.push(rep.load.server_queue_delay.mean);
        qd_p99.push(rep.load.server_queue_delay.p99);
        util.push(rep.load.server_utilization().unwrap_or(0.0));
        imb.push(rep.load.shard_imbalance().unwrap_or(0.0));
    }
    let avg = crate::stats::describe::mean;
    ShardCellResult {
        cell: cell.clone(),
        mean_ttft: avg(&mean_ttft),
        p99_ttft: avg(&p99_ttft),
        mean_queue_delay: avg(&qd_mean),
        p99_queue_delay: avg(&qd_p99),
        server_utilization: avg(&util),
        imbalance: avg(&imb),
    }
}

/// Render a grid as the experiment's text table.
pub fn render_grid(results: &[ShardCellResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.cell.shards),
                r.cell.balancer.label().to_string(),
                format!("{:.2}", r.cell.rate_rps),
                format!("{:.3}", r.mean_ttft),
                format!("{:.3}", r.p99_ttft),
                format!("{:.3}", r.mean_queue_delay),
                format!("{:.3}", r.p99_queue_delay),
                format!("{:.2}", r.server_utilization),
                format!("{:.2}", r.imbalance),
            ]
        })
        .collect();
    render_table(
        &[
            "shards",
            "balancer",
            "rate (req/s)",
            "mean TTFT",
            "p99 TTFT",
            "mean queue",
            "p99 queue",
            "util",
            "imbalance",
        ],
        &rows,
    )
}

/// The `shard-sweep` experiment entry: default grid, CSV + table output.
pub fn shard_sweep(ctx: &ExpContext) -> anyhow::Result<String> {
    let params = ShardSweepParams {
        n_requests: ctx.n_requests.clamp(50, 400),
        n_seeds: ctx.n_seeds.clamp(1, 3),
        ..Default::default()
    };
    let results = run_grid(&params);
    let mut csv = CsvWriter::new(&[
        "shards",
        "balancer",
        "rate_rps",
        "mean_ttft",
        "p99_ttft",
        "mean_queue_delay",
        "p99_queue_delay",
        "server_utilization",
        "imbalance",
    ]);
    for r in &results {
        csv.rowd(&[
            format!("{}", r.cell.shards),
            r.cell.balancer.label().to_string(),
            format!("{:.3}", r.cell.rate_rps),
            format!("{:.4}", r.mean_ttft),
            format!("{:.4}", r.p99_ttft),
            format!("{:.4}", r.mean_queue_delay),
            format!("{:.4}", r.p99_queue_delay),
            format!("{:.4}", r.server_utilization),
            format!("{:.4}", r.imbalance),
        ]);
    }
    csv.write(&ctx.csv_path("shard-sweep"))?;
    Ok(render_grid(&results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> ShardSweepParams {
        ShardSweepParams {
            shard_counts: vec![1, 2],
            balancers: vec![BalancerKind::RoundRobin, BalancerKind::JoinShortestQueue],
            rates: vec![0.5, 2.0],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_all_axes_in_order() {
        let params = tiny_params();
        let results = run_grid(&params);
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.cell.shards, params.shard_counts[i / 4]);
            assert_eq!(r.cell.balancer, params.balancers[(i / 2) % 2]);
            assert_eq!(r.cell.rate_rps, params.rates[i % 2]);
            assert!(r.mean_ttft > 0.0);
            assert!(r.server_utilization <= 1.0 + 1e-9);
        }
        // At K=1 the balancer is bypassed: RR and JSQ cells are
        // bit-identical.
        for j in 0..2 {
            assert_eq!(
                results[j].p99_ttft.to_bits(),
                results[j + 2].p99_ttft.to_bits(),
                "K=1 balancers must coincide"
            );
        }
    }

    #[test]
    fn same_cell_reproduces_regardless_of_grid_shape() {
        let solo = run_grid(&ShardSweepParams {
            shard_counts: vec![2],
            balancers: vec![BalancerKind::JoinShortestQueue],
            rates: vec![2.0],
            n_requests: 60,
            n_seeds: 1,
            ..Default::default()
        });
        let grid = run_grid(&tiny_params());
        let in_grid = grid
            .iter()
            .find(|r| {
                r.cell.shards == 2
                    && r.cell.balancer == BalancerKind::JoinShortestQueue
                    && r.cell.rate_rps == 2.0
            })
            .unwrap();
        assert_eq!(solo[0].mean_ttft.to_bits(), in_grid.mean_ttft.to_bits());
        assert_eq!(
            solo[0].p99_queue_delay.to_bits(),
            in_grid.p99_queue_delay.to_bits()
        );
    }

    #[test]
    fn shard_sweep_writes_csv() {
        let ctx = ExpContext {
            out_dir: std::env::temp_dir().join("disco_exp_shard_sweep"),
            n_seeds: 1,
            n_requests: 50,
        };
        let out = shard_sweep(&ctx).unwrap();
        assert!(out.contains("balancer"));
        let csv = std::fs::read_to_string(ctx.csv_path("shard-sweep")).unwrap();
        // Header + 4 shard counts × 4 balancers × 3 rates.
        assert_eq!(csv.lines().count(), 1 + 48);
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
    }
}
