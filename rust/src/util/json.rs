//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for trace files (JSON-lines), artifact manifests, and experiment
//! result metadata. `serde`/`serde_json` are not in the offline vendor
//! set, so this is a small self-contained implementation covering the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Fetch `key` as f64 or error — convenient for manifest decoding.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3.5", "-2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_object_deterministic() {
        let v = Json::obj(vec![
            ("z", Json::num(1.0)),
            ("a", Json::str("s")),
            ("m", Json::arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s1 = v.to_string();
        let s2 = Json::parse(&s1).unwrap().to_string();
        assert_eq!(s1, s2);
        // BTreeMap => keys sorted.
        assert!(s1.find("\"a\"").unwrap() < s1.find("\"z\"").unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"n": 2, "s": "x"}"#).unwrap();
        assert_eq!(v.req_f64("n").unwrap(), 2.0);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!(v.req_f64("missing").is_err());
    }
}
