//! Lightweight logger backend for the `log` facade.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::Once;
use std::time::Instant;

static INIT: Once = Once::new();
static mut START: Option<Instant> = None;

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        // SAFETY: START is written once under `Once` before any logging.
        let elapsed = unsafe {
            #[allow(static_mut_refs)]
            START.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
        };
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{elapsed:10.4}s {tag}] {}", record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Initialize logging once. Level comes from `DISCO_LOG`
/// (error|warn|info|debug|trace), defaulting to `info`.
pub fn init() {
    INIT.call_once(|| {
        unsafe {
            START = Some(Instant::now());
        }
        let level = match std::env::var("DISCO_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
