//! Scoped-thread parallel mapping shared by the sweep runner
//! (across-cell parallelism) and the zoned fleet simulator
//! (within-cell parallelism, `sim/zones.rs`).
//!
//! Determinism is preserved by construction: callers derive every RNG
//! stream from item content (cell seeds, zone ids) — never from thread
//! identity — and [`par_map`] lands results by input index, so output is
//! byte-identical for any worker count, including serial.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-thread count: `DISCO_THREADS` override, else available cores.
///
/// * unset — all available cores;
/// * `DISCO_THREADS=0` or `=1` — explicit serial (one worker);
/// * `DISCO_THREADS=N` — exactly N workers;
/// * unparsable — a warning is logged and all cores are used (the
///   unset behavior), so a typo degrades loudly rather than silently
///   changing the worker count.
pub fn worker_threads() -> usize {
    let all_cores = || {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    };
    match std::env::var("DISCO_THREADS") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(0) => 1, // explicit serial, not "all cores"
            Ok(n) => n,
            Err(_) => {
                log::warn!("DISCO_THREADS={s:?} is not a number; using all available cores");
                all_cores()
            }
        },
        Err(_) => all_cores(),
    }
}

/// Map `f` over `items` on scoped worker threads, preserving input order.
///
/// Work is distributed by an atomic cursor (cheap dynamic balancing for
/// uneven items); outputs are returned in input order regardless of which
/// thread computed them, so parallel runs stay deterministic as long as
/// `f(i, item)` itself is (all simulator cells and zones are: they seed
/// their own RNGs). Panics in `f` propagate.
pub fn par_map<I, O, F>(items: &[I], f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let n = items.len();
    let threads = worker_threads().min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let f = &f;
    let cursor = &cursor;
    let mut indexed: Vec<(usize, O)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i, &items[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("parallel worker panicked"))
            .collect()
    });
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, o)| o).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Env-var tests share the process environment; serialize them so a
    // concurrent test runner cannot interleave set/remove pairs.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn with_disco_threads<R>(val: Option<&str>, body: impl FnOnce() -> R) -> R {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let saved = std::env::var("DISCO_THREADS").ok();
        match val {
            Some(v) => std::env::set_var("DISCO_THREADS", v),
            None => std::env::remove_var("DISCO_THREADS"),
        }
        let out = body();
        match saved {
            Some(v) => std::env::set_var("DISCO_THREADS", v),
            None => std::env::remove_var("DISCO_THREADS"),
        }
        out
    }

    #[test]
    fn worker_threads_parses_explicit_counts() {
        assert_eq!(with_disco_threads(Some("1"), worker_threads), 1);
        assert_eq!(with_disco_threads(Some("4"), worker_threads), 4);
        assert_eq!(with_disco_threads(Some(" 2 "), worker_threads), 2);
    }

    #[test]
    fn worker_threads_zero_means_serial_not_all_cores() {
        assert_eq!(with_disco_threads(Some("0"), worker_threads), 1);
    }

    #[test]
    fn worker_threads_garbage_falls_back_to_all_cores() {
        let cores = with_disco_threads(None, worker_threads);
        assert!(cores >= 1);
        assert_eq!(with_disco_threads(Some("lots"), worker_threads), cores);
        assert_eq!(with_disco_threads(Some(""), worker_threads), cores);
        assert_eq!(with_disco_threads(Some("-3"), worker_threads), cores);
    }

    #[test]
    fn par_map_is_thread_count_invariant() {
        let items: Vec<u64> = (0..64).collect();
        let serial = with_disco_threads(Some("1"), || par_map(&items, |_, &x| x * 3 + 1));
        let parallel = with_disco_threads(Some("4"), || par_map(&items, |_, &x| x * 3 + 1));
        assert_eq!(serial, parallel);
    }
}
