//! Zero-dependency substrates.
//!
//! The offline vendor set available in this environment lacks the usual
//! ecosystem crates (`rand`, `serde`, `clap`, `tokio`, `criterion`,
//! `proptest`), so the pieces of them this project needs are implemented
//! here from scratch. Each sub-module is small, tested, and deterministic.

pub mod cli;
pub mod csv;
pub mod json;
pub mod label;
pub mod logging;
pub mod par;
pub mod rng;

/// Format a float with fixed precision, trimming to a compact display.
pub fn fmt_f64(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Render a simple aligned text table (used by experiment printers).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:<width$}", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    let total: usize = widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
    out.push_str(&"-".repeat(total));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "2.5".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
        assert!(lines[3].starts_with("long-name"));
    }

    #[test]
    fn fmt_f64_precision() {
        assert_eq!(fmt_f64(1.23456, 2), "1.23");
        assert_eq!(fmt_f64(-0.5, 3), "-0.500");
    }
}
