//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a usage printer.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// `known_flags` lists boolean options that never take a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        args.flags.push(body.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        args.options.insert(body.to_string(), v);
                    }
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the real process arguments.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), &["verbose"])
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--seed", "42", "--name=gpt", "trailing"]);
        assert_eq!(a.positional, vec!["run", "trailing"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get("name"), Some("gpt"));
    }

    #[test]
    fn known_flag_takes_no_value() {
        let a = parse(&["--verbose", "cmd"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn flag_before_another_option() {
        let a = parse(&["--dry", "--seed", "1"]);
        assert!(a.flag("dry"));
        assert_eq!(a.get("seed"), Some("1"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--last"]);
        assert!(a.flag("last"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--b", "0.5", "--n", "10"]);
        assert_eq!(a.get_f64("b", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 10);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        let bad = parse(&["--b", "xx", "--end"]);
        assert!(bad.get_f64("b", 0.0).is_err());
    }
}
