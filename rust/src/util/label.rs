//! One label-parsing convention for every CLI-facing enum.
//!
//! Each configurable kind in the simulator (`BalancerKind`,
//! `AutoscalerKind`, `BatchLatencyCurve`, `EventQueueKind`, `KvConfig`)
//! historically grew its own `parse()` with its own failure behavior.
//! [`ParseLabel`] pins the shared contract in one place:
//!
//! * parsing is case-insensitive on the head keyword;
//! * **trailing fields are rejected** — a typo'd arity must error, not
//!   silently run a different configuration (the `knee:4:0.05:9`
//!   regression);
//! * failures surface through [`ParseLabel::from_label`] with a uniform
//!   message that names the label family and lists the valid spellings,
//!   so every `--balancer`/`--autoscaler`/`--curve`/`--queue`/`--kv`
//!   flag errors the same way.
//!
//! The per-type `parse()` methods remain the implementation (and stay
//! callable directly); this trait is the convention layer the CLI goes
//! through.

use crate::sim::autoscaler::AutoscalerKind;
use crate::sim::balancer::BalancerKind;
use crate::sim::batching::BatchLatencyCurve;
use crate::sim::event_queue::EventQueueKind;
use crate::sim::fleet::PoolRole;
use crate::sim::kv::KvConfig;

/// Uniform label parsing for CLI-facing enums.
pub trait ParseLabel: Sized {
    /// Human name of the label family ("balancer", "curve", ...), used
    /// in error messages.
    const WHAT: &'static str;

    /// Compact list of valid spellings, used in error messages.
    const VALID: &'static str;

    /// Parse one spelling. `None` on an unknown keyword, a malformed
    /// field, or a trailing field.
    fn parse_label(s: &str) -> Option<Self>;

    /// [`ParseLabel::parse_label`] with the uniform error message:
    /// `unknown {WHAT} '{s}' (valid: {VALID})`.
    fn from_label(s: &str) -> anyhow::Result<Self> {
        Self::parse_label(s).ok_or_else(|| {
            anyhow::anyhow!("unknown {} '{}' (valid: {})", Self::WHAT, s, Self::VALID)
        })
    }
}

impl ParseLabel for BalancerKind {
    const WHAT: &'static str = "balancer";
    const VALID: &'static str = "rr, jsq, p2c, least-work (plus long-form aliases)";
    fn parse_label(s: &str) -> Option<Self> {
        BalancerKind::parse(s)
    }
}

impl ParseLabel for AutoscalerKind {
    const WHAT: &'static str = "autoscaler";
    const VALID: &'static str = "none, reactive, ttft-target (plus aliases)";
    fn parse_label(s: &str) -> Option<Self> {
        AutoscalerKind::parse(s)
    }
}

impl ParseLabel for BatchLatencyCurve {
    const WHAT: &'static str = "batch latency curve";
    const VALID: &'static str = "flat, linear[:ALPHA], knee[:K[:ALPHA]]";
    fn parse_label(s: &str) -> Option<Self> {
        BatchLatencyCurve::parse(s)
    }
}

impl ParseLabel for EventQueueKind {
    const WHAT: &'static str = "event queue";
    const VALID: &'static str = "wheel, heap (plus aliases)";
    fn parse_label(s: &str) -> Option<Self> {
        EventQueueKind::parse(s)
    }
}

impl ParseLabel for KvConfig {
    const WHAT: &'static str = "kv config";
    const VALID: &'static str = "PAGES[:BLOCK[:CHUNK[:cache|nocache]]]";
    fn parse_label(s: &str) -> Option<Self> {
        KvConfig::parse(s)
    }
}

impl ParseLabel for PoolRole {
    const WHAT: &'static str = "pool role";
    const VALID: &'static str = "unified (alias colocated), prefill (alias p), decode (alias d)";
    fn parse_label(s: &str) -> Option<Self> {
        PoolRole::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every canonical label round-trips through the trait, and the
    /// documented aliases resolve to the same variant.
    #[test]
    fn balancer_labels_round_trip() {
        for kind in BalancerKind::all() {
            assert_eq!(BalancerKind::parse_label(kind.label()), Some(kind));
        }
        for (alias, want) in [
            ("round-robin", BalancerKind::RoundRobin),
            ("roundrobin", BalancerKind::RoundRobin),
            ("join-shortest-queue", BalancerKind::JoinShortestQueue),
            ("shortest-queue", BalancerKind::JoinShortestQueue),
            ("power-of-two", BalancerKind::PowerOfTwoChoices),
            ("power-of-two-choices", BalancerKind::PowerOfTwoChoices),
            ("lw", BalancerKind::LeastWork),
            ("leastwork", BalancerKind::LeastWork),
            ("RR", BalancerKind::RoundRobin),
        ] {
            assert_eq!(BalancerKind::parse_label(alias), Some(want), "{alias}");
        }
    }

    #[test]
    fn autoscaler_labels_round_trip() {
        for (alias, want) in [
            ("none", "none"),
            ("fixed", "none"),
            ("static", "none"),
            ("reactive", "reactive"),
            ("queue", "reactive"),
            ("ttft", "ttft-target"),
            ("ttft-target", "ttft-target"),
            ("deadline", "ttft-target"),
        ] {
            let got = AutoscalerKind::parse_label(alias).unwrap_or_else(|| {
                panic!("alias {alias} must parse");
            });
            assert_eq!(got.label(), want, "{alias}");
        }
    }

    #[test]
    fn event_queue_labels_round_trip() {
        for kind in EventQueueKind::all() {
            assert_eq!(EventQueueKind::parse_label(kind.label()), Some(kind));
        }
        assert_eq!(
            EventQueueKind::parse_label("timing-wheel"),
            Some(EventQueueKind::Wheel)
        );
        assert_eq!(
            EventQueueKind::parse_label("binary-heap"),
            Some(EventQueueKind::Heap)
        );
    }

    #[test]
    fn curve_labels_round_trip() {
        for curve in [
            BatchLatencyCurve::Flat,
            BatchLatencyCurve::Linear { alpha: 0.3 },
            BatchLatencyCurve::Knee { knee: 4, alpha: 0.5 },
        ] {
            assert_eq!(BatchLatencyCurve::parse_label(&curve.label()), Some(curve));
        }
        // Bare spellings take the documented defaults.
        assert_eq!(
            BatchLatencyCurve::parse_label("linear"),
            Some(BatchLatencyCurve::Linear { alpha: 0.05 })
        );
        assert_eq!(
            BatchLatencyCurve::parse_label("knee"),
            Some(BatchLatencyCurve::Knee { knee: 8, alpha: 0.05 })
        );
    }

    #[test]
    fn kv_config_labels_round_trip() {
        let full = KvConfig {
            pages: 4096,
            block_tokens: 32,
            chunk_tokens: 128,
            prefix_caching: false,
            ..KvConfig::default()
        };
        assert_eq!(KvConfig::parse_label(&full.label()), Some(full));
        // Short spellings fill the tail with defaults.
        let short = KvConfig::parse_label("1024").unwrap();
        assert_eq!(short.pages, 1024);
        assert_eq!(short.block_tokens, KvConfig::default().block_tokens);
        assert!(short.prefix_caching);
        let mid = KvConfig::parse_label("1024:8:64").unwrap();
        assert_eq!((mid.pages, mid.block_tokens, mid.chunk_tokens), (1024, 8, 64));
    }

    #[test]
    fn pool_role_labels_round_trip() {
        for role in [PoolRole::Unified, PoolRole::Prefill, PoolRole::Decode] {
            assert_eq!(PoolRole::parse_label(role.label()), Some(role));
        }
        for (alias, want) in [
            ("colocated", PoolRole::Unified),
            ("p", PoolRole::Prefill),
            ("d", PoolRole::Decode),
            ("DECODE", PoolRole::Decode),
        ] {
            assert_eq!(PoolRole::parse_label(alias), Some(want), "{alias}");
        }
    }

    /// The PR-5 regression class: a trailing field must reject across
    /// the whole convention, not silently run a different config.
    #[test]
    fn trailing_fields_reject_everywhere() {
        assert_eq!(BatchLatencyCurve::parse_label("knee:4:0.05:9"), None);
        assert_eq!(BatchLatencyCurve::parse_label("linear:0.05:9"), None);
        assert_eq!(BatchLatencyCurve::parse_label("flat:1"), None);
        assert_eq!(KvConfig::parse_label("4096:16:256:cache:x"), None);
        assert_eq!(BalancerKind::parse_label("rr:extra"), None);
        assert_eq!(AutoscalerKind::parse_label("reactive:fast"), None);
        assert_eq!(EventQueueKind::parse_label("wheel:extra"), None);
        assert_eq!(PoolRole::parse_label("prefill:extra"), None);
    }

    #[test]
    fn unknown_labels_error_uniformly() {
        let err = BalancerKind::from_label("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown balancer 'bogus'"), "{err}");
        assert!(err.contains("valid: rr"), "{err}");
        let err = KvConfig::from_label("four-thousand").unwrap_err().to_string();
        assert!(err.contains("unknown kv config"), "{err}");
        assert!(err.contains("PAGES"), "{err}");
        assert!(BatchLatencyCurve::from_label("flat").is_ok());
    }
}
