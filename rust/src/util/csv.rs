//! Tiny CSV writer used by the experiment harness to dump `results/*.csv`.

use std::fs;
use std::io::Write;
use std::path::Path;

/// Accumulates rows and writes an RFC-4180-ish CSV file.
pub struct CsvWriter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(headers: &[&str]) -> Self {
        CsvWriter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the column count mismatches the header.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "CSV row width mismatch ({} vs {})",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    /// Convenience: append a row of displayable values.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) {
        self.row(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&Self::encode_row(&self.headers));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&Self::encode_row(row));
            out.push('\n');
        }
        out
    }

    fn encode_row(cells: &[String]) -> String {
        cells
            .iter()
            .map(|c| Self::escape(c))
            .collect::<Vec<_>>()
            .join(",")
    }

    fn escape(cell: &str) -> String {
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    /// Write to disk, creating parent directories.
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.rowd(&["1", "2"]);
        w.rowd(&["x,y", "q\"t"]);
        let s = w.to_string();
        assert_eq!(s, "a,b\n1,2\n\"x,y\",\"q\"\"t\"\n");
        assert_eq!(w.n_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.rowd(&["only-one"]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("disco_csv_test");
        let path = dir.join("out.csv");
        let mut w = CsvWriter::new(&["h"]);
        w.rowd(&["v"]);
        w.write(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(read, "h\nv\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
