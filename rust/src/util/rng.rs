//! Deterministic pseudo-random number generation.
//!
//! Implements splitmix64 (seeding) and xoshiro256++ (stream), the standard
//! small-state generators. All randomness in the repository flows through
//! [`Rng`] so that every experiment is reproducible bit-for-bit from its
//! seed. The `rand` crate is not available in the offline vendor set.

/// splitmix64 step; used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream; used to give each request /
    /// endpoint its own generator without cross-correlation.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (no cached spare: keeps state simple).
    pub fn normal(&mut self) -> f64 {
        // Avoid u1 == 0 exactly.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with parameters of the underlying normal (mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with given rate (1/mean).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().ln_1p_neg() / rate
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang squeeze (k ≥ 1) with
    /// the Ahrens–Dieter boost for k < 1. Used for non-Poisson arrival
    /// processes: a Gamma inter-arrival with k < 1 is burstier than
    /// exponential (CV > 1), k > 1 is smoother (CV < 1).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "gamma needs positive params");
        if shape < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) · U^(1/k).
            let u = loop {
                let u = self.f64();
                if u > 1e-300 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v * scale;
            }
            if u > 1e-300 && u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = self.normal_ms(mean, mean.sqrt()).round();
            if v < 0.0 {
                0
            } else {
                v as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive sum");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if r < *w {
                return i;
            }
            r -= *w;
        }
        weights.len() - 1
    }
}

/// `ln(1-x)` for x in [0,1): helper so `exponential` never takes ln(0).
trait Ln1pNeg {
    fn ln_1p_neg(self) -> f64;
}
impl Ln1pNeg for f64 {
    #[inline]
    fn ln_1p_neg(self) -> f64 {
        (-self).ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(9);
        let mu = 0.5f64;
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(mu, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[25_000];
        assert!((median - mu.exp()).abs() / mu.exp() < 0.05, "median={median}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let rate = 2.0;
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(19);
        let n = 60_000;
        for (shape, scale) in [(0.5, 2.0), (1.0, 1.5), (4.0, 0.25), (9.0, 3.0)] {
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape, scale)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            let (em, ev) = (shape * scale, shape * scale * scale);
            assert!((mean - em).abs() / em < 0.05, "k={shape}: mean={mean} vs {em}");
            assert!((var - ev).abs() / ev < 0.12, "k={shape}: var={var} vs {ev}");
            assert!(xs.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn gamma_shape_one_is_exponential() {
        // Gamma(1, θ) ≡ Exp(1/θ): compare tail mass at the 1-θ mark.
        let mut r = Rng::new(29);
        let n = 50_000;
        let tail = (0..n).filter(|_| r.gamma(1.0, 2.0) > 2.0).count() as f64 / n as f64;
        let expect = (-1.0f64).exp();
        assert!((tail - expect).abs() < 0.01, "tail={tail} vs {expect}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(17);
        for lam in [0.5, 4.0, 80.0] {
            let n = 30_000;
            let mean = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() / lam < 0.07, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 8 * c[0] / 2, "c={c:?}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
