//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration-count calibration, and robust
//! statistics (median, MAD, throughput). `cargo bench` targets use
//! `harness = false` and drive this directly.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median wall time per iteration (seconds).
    pub median: f64,
    /// Mean wall time per iteration (seconds).
    pub mean: f64,
    /// Median absolute deviation (seconds).
    pub mad: f64,
    /// Number of timed samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn per_iter_display(&self) -> String {
        fmt_duration(self.median)
    }
}

/// Pretty-print a duration in adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Benchmark runner with calibrated sample counts.
pub struct Bench {
    /// Target wall time per sample.
    sample_target: Duration,
    /// Number of timed samples to collect.
    n_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Honour quick mode for CI: DISCO_BENCH_FAST=1
        let fast = std::env::var("DISCO_BENCH_FAST").is_ok();
        Bench {
            sample_target: if fast {
                Duration::from_millis(5)
            } else {
                Duration::from_millis(50)
            },
            n_samples: if fast { 7 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform ONE logical iteration and return a
    /// value (passed through `black_box` to defeat dead-code elimination).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: find iters such that a sample ≈ target.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            let dt = t0.elapsed();
            if dt >= self.sample_target || iters > 1 << 30 {
                break;
            }
            let scale = (self.sample_target.as_secs_f64() / dt.as_secs_f64().max(1e-9))
                .clamp(1.5, 100.0);
            iters = ((iters as f64) * scale).ceil() as u64;
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.n_samples);
        for _ in 0..self.n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_iter[per_iter.len() / 2];
        let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
        let mut devs: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];

        let res = BenchResult {
            name: name.to_string(),
            median,
            mean,
            mad,
            samples: per_iter.len(),
            iters_per_sample: iters,
        };
        println!(
            "{:<44} {:>12}/iter  (mean {:>12}, ±{} MAD, {} iters × {} samples)",
            res.name,
            fmt_duration(res.median),
            fmt_duration(res.mean),
            fmt_duration(res.mad),
            res.iters_per_sample,
            res.samples,
        );
        self.results.push(res.clone());
        res
    }

    /// Report a throughput line for a result measured over `items` items.
    pub fn throughput(&self, res: &BenchResult, items: f64, unit: &str) {
        let per_sec = items / res.median;
        println!(
            "{:<44} {:>12.0} {unit}/s",
            format!("{} (throughput)", res.name),
            per_sec
        );
    }

    /// Write results as CSV next to other experiment outputs.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut w = crate::util::csv::CsvWriter::new(&[
            "name",
            "median_s",
            "mean_s",
            "mad_s",
            "iters_per_sample",
            "samples",
        ]);
        for r in &self.results {
            w.row(vec![
                r.name.clone(),
                format!("{:e}", r.median),
                format!("{:e}", r.mean),
                format!("{:e}", r.mad),
                r.iters_per_sample.to_string(),
                r.samples.to_string(),
            ]);
        }
        w.write(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("DISCO_BENCH_FAST", "1");
        let mut b = Bench::new();
        let r = b.run("noop-sum", || (0..100u64).sum::<u64>());
        assert!(r.median > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with(" s"));
    }
}
