//! Inference endpoints.
//!
//! An endpoint answers two questions for the simulator/scheduler:
//! *when does the first token arrive* (prefill) and *how do subsequent
//! tokens pace* (decode gaps). Simulated endpoints draw from calibrated
//! profiles; the real endpoint (in [`crate::runtime`]) executes an
//! AOT-compiled transformer via PJRT.

pub mod coldstart;
pub mod device;
pub mod server;

pub use device::DeviceEndpoint;
pub use server::ServerEndpoint;

use crate::util::rng::Rng;

/// Which side of the network an endpoint lives on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EndpointKind {
    Server,
    Device,
}

impl std::fmt::Display for EndpointKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EndpointKind::Server => write!(f, "server"),
            EndpointKind::Device => write!(f, "device"),
        }
    }
}

/// Timing model interface used by the discrete-event simulator.
pub trait SimEndpoint {
    fn kind(&self) -> EndpointKind;

    /// Seconds from request start to first token.
    fn sample_ttft(&self, prompt_len: u32, rng: &mut Rng) -> f64;

    /// Inter-token gaps for `n` decode tokens starting at context `ctx`.
    fn sample_gaps(&self, ctx: u32, n: u32, rng: &mut Rng) -> Vec<f64>;

    /// Expected decode rate (tokens/s) — used by migration planning.
    fn decode_rate(&self) -> f64;

    /// Expected TTFT for a prompt (used by migration planning for the
    /// re-prefill estimate). For servers this is the distribution mean.
    fn expected_ttft(&self, prompt_len: u32) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_display() {
        assert_eq!(EndpointKind::Server.to_string(), "server");
        assert_eq!(EndpointKind::Device.to_string(), "device");
    }
}
