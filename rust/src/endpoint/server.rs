//! Simulated on-server endpoint.
//!
//! Wraps a [`ServerProfile`] behind the [`SimEndpoint`] interface. Server
//! TTFT already folds in queueing, batching interference, and last-hop
//! network latency (§2.3) — that is precisely why it is modeled as a
//! length-independent heavy-tailed distribution rather than a mechanistic
//! queue: the paper's dispatcher treats it as an opaque profiled CDF.

use crate::endpoint::{EndpointKind, SimEndpoint};
use crate::profiles::server::ServerProfile;
use crate::util::rng::Rng;

/// Server endpoint driven by a calibrated service profile.
#[derive(Clone, Debug)]
pub struct ServerEndpoint {
    pub profile: ServerProfile,
    /// Additional fixed last-hop RTT folded into every TTFT (seconds).
    pub extra_rtt: f64,
}

impl ServerEndpoint {
    pub fn new(profile: ServerProfile) -> ServerEndpoint {
        ServerEndpoint {
            profile,
            extra_rtt: 0.0,
        }
    }

    pub fn with_rtt(profile: ServerProfile, extra_rtt: f64) -> ServerEndpoint {
        ServerEndpoint { profile, extra_rtt }
    }

    /// Build per-shard endpoints for a sharded fleet: one endpoint per
    /// RTT offset, each adding its shard's offset on top of the base
    /// endpoint's own `extra_rtt`. An all-zero offset vector yields
    /// endpoints byte-identical to the base (the homogeneous fleet), so
    /// the K=1 replay parity is preserved by construction.
    pub fn shard_fleet(base: &ServerEndpoint, rtt_offsets: &[f64]) -> Vec<ServerEndpoint> {
        rtt_offsets
            .iter()
            .map(|&dr| ServerEndpoint {
                profile: base.profile.clone(),
                extra_rtt: base.extra_rtt + dr,
            })
            .collect()
    }
}

impl SimEndpoint for ServerEndpoint {
    fn kind(&self) -> EndpointKind {
        EndpointKind::Server
    }

    fn sample_ttft(&self, _prompt_len: u32, rng: &mut Rng) -> f64 {
        // Length-independent (Table 1).
        self.extra_rtt + self.profile.sample_ttft(rng)
    }

    fn sample_gaps(&self, _ctx: u32, n: u32, rng: &mut Rng) -> Vec<f64> {
        self.profile.sample_gaps(n, rng)
    }

    fn decode_rate(&self) -> f64 {
        self.profile.decode_rate()
    }

    fn expected_ttft(&self, _prompt_len: u32) -> f64 {
        self.extra_rtt + self.profile.mean_ttft()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::corr::pearson;

    #[test]
    fn ttft_is_length_independent() {
        let ep = ServerEndpoint::new(ServerProfile::gpt4o_mini());
        let mut rng = Rng::new(21);
        let lens: Vec<u32> = (0..3000).map(|_| rng.range_u64(4, 2048) as u32).collect();
        let xs: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
        let ys: Vec<f64> = lens
            .iter()
            .map(|&l| ep.sample_ttft(l, &mut rng))
            .collect();
        let r = pearson(&xs, &ys);
        assert!(r.abs() < 0.06, "pearson={r}, Table 1 reports ~0.02");
    }

    #[test]
    fn extra_rtt_shifts_ttft() {
        let base = ServerEndpoint::new(ServerProfile::command());
        let shifted = ServerEndpoint::with_rtt(ServerProfile::command(), 0.5);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = base.sample_ttft(10, &mut r1);
        let b = shifted.sample_ttft(10, &mut r2);
        assert!((b - a - 0.5).abs() < 1e-12);
        assert!((shifted.expected_ttft(10) - base.expected_ttft(10) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shard_fleet_offsets_stack_on_base_rtt() {
        let base = ServerEndpoint::with_rtt(ServerProfile::gpt4o_mini(), 0.1);
        let eps = ServerEndpoint::shard_fleet(&base, &[0.0, 0.25]);
        assert_eq!(eps.len(), 2);
        assert_eq!(eps[0].extra_rtt, 0.1);
        assert_eq!(eps[1].extra_rtt, 0.35);
        // Zero offset reproduces the base endpoint's samples exactly.
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(
            base.sample_ttft(32, &mut r1).to_bits(),
            eps[0].sample_ttft(32, &mut r2).to_bits()
        );
    }

    #[test]
    fn gap_count_matches_request() {
        let ep = ServerEndpoint::new(ServerProfile::deepseek_v25());
        let mut rng = Rng::new(2);
        assert_eq!(ep.sample_gaps(0, 57, &mut rng).len(), 57);
        assert_eq!(ep.kind(), EndpointKind::Server);
    }
}
