//! Cold-start model (Appendix B, Table 4).
//!
//! On-device models are not always resident: loading weights dominates
//! the first request's latency. Table 4 shows load time growing linearly
//! with parameter count while warm TTFT stays tens of milliseconds. The
//! model here: `load = intercept + params_gb / disk_gbps` and
//! `ttft = ttft_base + ttft_per_b × params_b`, fitted per platform to the
//! paper's measurements.

/// A host platform's cold-start characteristics.
#[derive(Clone, Copy, Debug)]
pub struct ColdStartProfile {
    pub platform: &'static str,
    /// Fixed load overhead (allocator, runtime init), seconds.
    pub load_intercept: f64,
    /// Effective weight-streaming bandwidth, GB/s (fp16 weights).
    pub disk_gbps: f64,
    /// Warm-TTFT intercept, seconds.
    pub ttft_base: f64,
    /// Warm-TTFT slope per billion parameters, seconds.
    pub ttft_per_b: f64,
    /// GPU memory capacity in GB (models beyond this cannot load).
    pub vram_gb: f64,
}

impl ColdStartProfile {
    /// Windows 10 + RTX 3060 12 GB (Table 4 upper half).
    pub fn rtx3060() -> ColdStartProfile {
        ColdStartProfile {
            platform: "RTX 3060 12GB",
            load_intercept: 0.55,
            disk_gbps: 1.55,
            ttft_base: 0.032,
            ttft_per_b: 0.038,
            vram_gb: 12.0,
        }
    }

    /// Linux + A40 48 GB (Table 4 lower half): slower effective load path,
    /// much faster and size-insensitive compute.
    pub fn a40() -> ColdStartProfile {
        ColdStartProfile {
            platform: "A40 48GB",
            load_intercept: 0.48,
            disk_gbps: 1.02,
            ttft_base: 0.024,
            ttft_per_b: 0.0013,
            vram_gb: 48.0,
        }
    }

    /// Can this platform host a model of `params_b` billion fp16 params?
    pub fn fits(&self, params_b: f64) -> bool {
        // fp16 weights + ~25% runtime overhead must fit in VRAM.
        params_b * 2.0 * 1.25 <= self.vram_gb
    }

    /// Model load (cold start) time in seconds.
    pub fn load_time(&self, params_b: f64) -> f64 {
        self.load_intercept + params_b * 2.0 / self.disk_gbps
    }

    /// Warm TTFT for a short prompt.
    pub fn warm_ttft(&self, params_b: f64) -> f64 {
        self.ttft_base + self.ttft_per_b * params_b
    }

    /// First-request latency = load + warm TTFT.
    pub fn cold_ttft(&self, params_b: f64) -> f64 {
        self.load_time(params_b) + self.warm_ttft(params_b)
    }
}

/// Qwen-2.5 model sizes measured in Table 4 (billions of parameters).
pub const QWEN_SIZES_B: &[(&str, f64)] = &[
    ("0.5B", 0.5),
    ("1.5B", 1.5),
    ("3B", 3.0),
    ("7B", 7.0),
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 4: fitted model must land near every measured cell.
    #[test]
    fn matches_table4_measurements() {
        let rtx = ColdStartProfile::rtx3060();
        let a40 = ColdStartProfile::a40();
        // (params_b, load_s, ttft_s)
        let rtx_rows = [(0.5, 1.29, 0.051), (1.5, 2.48, 0.105), (3.0, 4.45, 0.145)];
        let a40_rows = [
            (0.5, 1.53, 0.025),
            (1.5, 3.12, 0.026),
            (3.0, 5.72, 0.033),
            (7.0, 13.43, 0.033),
        ];
        for (b, load, ttft) in rtx_rows {
            assert!(
                (rtx.load_time(b) - load).abs() / load < 0.15,
                "rtx load {b}B: {} vs {load}",
                rtx.load_time(b)
            );
            assert!(
                (rtx.warm_ttft(b) - ttft).abs() < 0.03,
                "rtx ttft {b}B: {} vs {ttft}",
                rtx.warm_ttft(b)
            );
        }
        for (b, load, ttft) in a40_rows {
            assert!(
                (a40.load_time(b) - load).abs() / load < 0.15,
                "a40 load {b}B: {} vs {load}",
                a40.load_time(b)
            );
            assert!(
                (a40.warm_ttft(b) - ttft).abs() < 0.012,
                "a40 ttft {b}B: {} vs {ttft}",
                a40.warm_ttft(b)
            );
        }
    }

    /// The 7B model exceeds the RTX 3060's memory (Table 4 footnote).
    #[test]
    fn memory_capacity_gate() {
        assert!(!ColdStartProfile::rtx3060().fits(7.0));
        assert!(ColdStartProfile::rtx3060().fits(3.0));
        assert!(ColdStartProfile::a40().fits(7.0));
    }

    /// Appendix B's headline: loading dominates cold TTFT.
    #[test]
    fn load_dominates_cold_start() {
        for p in [ColdStartProfile::rtx3060(), ColdStartProfile::a40()] {
            for (_, b) in QWEN_SIZES_B.iter().take(3) {
                assert!(p.load_time(*b) > 10.0 * p.warm_ttft(*b));
                assert!(p.cold_ttft(*b) > p.load_time(*b));
            }
        }
    }

    /// `fits` at the capacity boundary: a model whose fp16 weights plus
    /// the 25% runtime overhead land a hair inside the VRAM limit fits;
    /// a hair beyond does not. (The exact boundary itself is subject to
    /// floating-point rounding of `vram / 2.5`, so the test brackets it.)
    #[test]
    fn fits_at_capacity_boundary() {
        for p in [ColdStartProfile::rtx3060(), ColdStartProfile::a40()] {
            // params_b × 2.0 × 1.25 == vram_gb at the boundary.
            let boundary = p.vram_gb / 2.5;
            assert!(
                p.fits(boundary * (1.0 - 1e-9)),
                "{}: just inside the boundary must fit",
                p.platform
            );
            assert!(
                !p.fits(boundary * (1.0 + 1e-9)),
                "{}: just over the boundary must not fit",
                p.platform
            );
            // Boundary models still have finite, load-dominated cold
            // starts.
            assert!(p.load_time(boundary).is_finite());
            assert!(p.cold_ttft(boundary) > p.load_time(boundary));
        }
    }

    /// Zero-parameter degenerate model: the intercepts survive — load
    /// time is pure runtime init, warm TTFT is the base latency, and the
    /// cold TTFT is exactly their sum.
    #[test]
    fn zero_parameter_model_reduces_to_intercepts() {
        for p in [ColdStartProfile::rtx3060(), ColdStartProfile::a40()] {
            assert!(p.fits(0.0), "{}: a 0B model always fits", p.platform);
            assert_eq!(p.load_time(0.0), p.load_intercept);
            assert_eq!(p.warm_ttft(0.0), p.ttft_base);
            assert_eq!(p.cold_ttft(0.0), p.load_intercept + p.ttft_base);
            assert!(p.load_time(0.0) > 0.0 && p.warm_ttft(0.0) > 0.0);
        }
    }

    /// Load time and cold TTFT grow monotonically in model size (the
    /// linear Table-4 fit), so the autoscaler's cold-start penalty is
    /// well-ordered across model choices.
    #[test]
    fn cold_start_monotone_in_model_size() {
        for p in [ColdStartProfile::rtx3060(), ColdStartProfile::a40()] {
            let mut last_load = -1.0;
            let mut last_cold = -1.0;
            for (_, b) in QWEN_SIZES_B {
                assert!(p.load_time(*b) > last_load);
                assert!(p.cold_ttft(*b) > last_cold);
                last_load = p.load_time(*b);
                last_cold = p.cold_ttft(*b);
            }
        }
    }
}
