//! Simulated on-device endpoint.
//!
//! Wraps a [`DeviceProfile`]: linear-in-length prefill, steady decode,
//! FLOPs-based energy metering, and single-inference-at-a-time occupancy
//! (the simulator serializes device work through `busy_until`).

use crate::endpoint::{EndpointKind, SimEndpoint};
use crate::profiles::device::DeviceProfile;
use crate::util::rng::Rng;

/// Device endpoint driven by a mobile (or local GPU) profile.
#[derive(Clone, Debug)]
pub struct DeviceEndpoint {
    pub profile: DeviceProfile,
}

impl DeviceEndpoint {
    pub fn new(profile: DeviceProfile) -> DeviceEndpoint {
        DeviceEndpoint { profile }
    }

    /// FLOPs charged for a prefill of `l` tokens (energy accounting).
    pub fn prefill_flops(&self, l: u32) -> f64 {
        self.profile.prefill_flops(l)
    }

    /// FLOPs charged for decoding `n` tokens from context `l0`.
    pub fn decode_flops(&self, l0: u32, n: u32) -> f64 {
        self.profile.decode_flops(l0, n)
    }
}

impl SimEndpoint for DeviceEndpoint {
    fn kind(&self) -> EndpointKind {
        EndpointKind::Device
    }

    fn sample_ttft(&self, prompt_len: u32, rng: &mut Rng) -> f64 {
        self.profile.sample_ttft(prompt_len, rng)
    }

    fn sample_gaps(&self, _ctx: u32, n: u32, rng: &mut Rng) -> Vec<f64> {
        self.profile.sample_gaps(n, rng)
    }

    fn decode_rate(&self) -> f64 {
        self.profile.decode_tps
    }

    fn expected_ttft(&self, prompt_len: u32) -> f64 {
        self.profile.ttft_expected(prompt_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_scaling() {
        let ep = DeviceEndpoint::new(DeviceProfile::pixel7pro_bloom1b1());
        let t100 = ep.expected_ttft(100);
        let t200 = ep.expected_ttft(200);
        // Slope = 1/prefill_tps exactly.
        assert!(((t200 - t100) - 100.0 / 31.32).abs() < 1e-9);
        assert_eq!(ep.kind(), EndpointKind::Device);
    }

    #[test]
    fn sampled_near_expected() {
        let ep = DeviceEndpoint::new(DeviceProfile::xiaomi14_qwen0b5());
        let mut rng = Rng::new(8);
        let samples: Vec<f64> = (0..500).map(|_| ep.sample_ttft(160, &mut rng)).collect();
        let mean = crate::stats::describe::mean(&samples);
        let exp = ep.expected_ttft(160);
        assert!((mean - exp).abs() / exp < 0.02, "mean={mean} exp={exp}");
    }

    #[test]
    fn energy_meters_positive() {
        let ep = DeviceEndpoint::new(DeviceProfile::pixel7pro_bloom560m());
        assert!(ep.prefill_flops(64) > 0.0);
        assert!(ep.decode_flops(64, 32) > 0.0);
    }
}
