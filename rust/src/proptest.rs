//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random inputs; on failure it reports
//! the failing case number and seed so the case can be replayed
//! deterministically. Shrinking is out of scope — failures carry the full
//! generated input via `Debug` formatting instead.

use crate::util::rng::Rng;

/// Number of cases per property (override with DISCO_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("DISCO_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` over `cases` inputs drawn by `gen` from a seeded RNG.
/// Panics (test failure) with seed + case context when the property fails.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = 0xD15C0u64;
    for case in 0..cases {
        let mut rng =
            Rng::new(base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The migration invariant (§4.3): under *arbitrary* handoff timing —
    /// any migration point, any target warm-up, any source/target pacing —
    /// the delivered token stream has no gaps, no duplicates, and
    /// preserves order. In the timeline representation that means: the
    /// delivery schedule emits exactly one read time per generated token
    /// (count preserved ⇒ no gaps/duplicates), read times are monotone
    /// (order preserved across the handoff boundary), nothing is shown
    /// before it is generated, and pacing never beats the consumption
    /// rate.
    #[test]
    fn prop_migrated_stream_no_gaps_no_dups_order_preserved() {
        check(
            "migration-stream-integrity",
            256,
            |r| {
                let n = 2 + r.below(200) as usize;
                let ttft = 0.02 + r.f64();
                let r_c = 1.0 + r.f64() * 9.0;
                // Source stream up to a random handoff index m ∈ [1, n).
                let m = 1 + r.below(n as u64 - 1) as usize;
                let mut gen = Vec::with_capacity(n);
                gen.push(ttft);
                for _ in 1..m {
                    let g = r.f64() * 0.4;
                    gen.push(gen.last().unwrap() + g);
                }
                // Handoff: the target re-prefills for t_m (arbitrary, up
                // to several consumption intervals), then paces the tail.
                let t_m = r.f64() * 3.0;
                gen.push(gen.last().unwrap() + t_m);
                for _ in (m + 1)..n {
                    let g = r.f64() * 0.4;
                    gen.push(gen.last().unwrap() + g);
                }
                (gen, r_c)
            },
            |(gen, r_c)| {
                let d = crate::sim::delivery::smooth(gen, *r_c);
                prop_assert!(
                    d.read_times.len() == gen.len(),
                    "token count changed across handoff: {} generated, {} delivered",
                    gen.len(),
                    d.read_times.len()
                );
                prop_assert!(
                    d.tbts.len() + 1 == gen.len(),
                    "perceived-gap count mismatch: {} tbts for {} tokens",
                    d.tbts.len(),
                    gen.len()
                );
                let step = 1.0 / r_c;
                for i in 1..d.read_times.len() {
                    prop_assert!(
                        d.read_times[i] >= d.read_times[i - 1],
                        "order violated at {i}"
                    );
                    prop_assert!(
                        d.read_times[i] + 1e-9 >= gen[i],
                        "token {i} delivered before generated"
                    );
                    prop_assert!(
                        d.read_times[i] + 1e-9 >= d.read_times[i - 1] + step,
                        "pacing beats consumption rate at {i}"
                    );
                    prop_assert!(d.tbts[i - 1] > 0.0, "non-positive perceived gap at {i}");
                }
                Ok(())
            },
        );
    }

    /// The same invariant end-to-end: run a migration-heavy scenario and
    /// check every record's stream accounting survives the handoff.
    #[test]
    fn prop_engine_migration_preserves_stream_accounting() {
        use crate::coordinator::policy::{Policy, PolicyKind};
        use crate::cost::unified::Constraint;
        use crate::profiles::{DeviceProfile, ServerProfile};
        use crate::sim::engine::{Scenario, SimConfig};
        use crate::trace::generator::WorkloadSpec;

        let sc = Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::pixel7pro_bloom1b1(),
            Constraint::Device,
            SimConfig {
                seed: 99,
                ..Default::default()
            },
        );
        let mut migrated_total = 0usize;
        check(
            "engine-migration-stream",
            16,
            |r| r.next_u64(),
            |&seed| {
                let trace = WorkloadSpec::alpaca(60).generate(seed);
                let ecdf = sc.profile_server_ttft(400, seed);
                let policy =
                    Policy::plan(PolicyKind::DiscoD, 0.7, true, &ecdf, &trace.prompt_lens());
                for rec in sc.run(&trace, &policy) {
                    if rec.migrated {
                        migrated_total += 1;
                    }
                    prop_assert!(
                        rec.tbts.len() as u32 == rec.output_len - 1,
                        "stream count broke for request {}",
                        rec.id
                    );
                    let decoded =
                        rec.cost.server_decode_tokens + rec.cost.device_decode_tokens;
                    prop_assert!(
                        decoded == rec.output_len as u64,
                        "decode conservation broke: {decoded} vs {}",
                        rec.output_len
                    );
                    prop_assert!(
                        rec.tbts.iter().all(|&t| t > 0.0),
                        "non-positive perceived gap in request {}",
                        rec.id
                    );
                }
                Ok(())
            },
        );
        assert!(migrated_total > 0, "property never exercised a migration");
    }

    /// The §4.3 invariant at FLEET scope, under shard targeting and
    /// mid-run shard failure: across randomized (K, balancer,
    /// outage-time, migration-config, **batching-mode**, **KV axis** —
    /// page-pool pressure, memory-pressure preemption, KV-lossy outage
    /// failover under small paged pools) inputs, every
    /// delivered stream — migrated or not, re-queued off a dead shard
    /// or not, decoding in a batch whose size changes mid-decode as
    /// neighbors join and leave — keeps its token accounting intact: no
    /// gaps (`tbts.len() + 1 == output_len`), no duplicates
    /// (decode-token conservation across endpoints), order preserved
    /// (strictly positive perceived gaps). This is
    /// `prop_migrated_stream_no_gaps_no_dups_order_preserved` lifted
    /// from a single stream to a migration storm on a failing fleet.
    ///
    /// A randomized subset of storms additionally re-runs on the
    /// binary-heap reference event queue and asserts the run is
    /// **byte-identical** to the default timing wheel — the event-queue
    /// determinism contract checked under the nastiest fleet dynamics
    /// the suite generates.
    ///
    /// A **P/D-disaggregation axis** splits ~a third of the K ≥ 2
    /// storms into prefill/decode pools: the same stream invariants
    /// must survive the extra KV-transfer handoff hop (including an
    /// outage landing on either pool), the handoff ledger must balance
    /// (`Σ handoff_in == handoff_count`), and undisaggregated storms
    /// must report zero handoff telemetry.
    ///
    /// Every storm also replays zone-partitioned (Z ∈ 1..=3 copies of
    /// the same failing fleet, `sim/zones.rs`): the merged stream must
    /// keep every invariant above, the merged load report must
    /// decompose exactly as the sum of its zones, Z=1 must be
    /// byte-identical to the unzoned run, and the zoned run must
    /// bit-replay.
    #[test]
    fn prop_fleet_migration_storm_under_outage_preserves_stream_integrity() {
        use crate::coordinator::policy::{Policy, PolicyKind};
        use crate::cost::unified::Constraint;
        use crate::profiles::{DeviceProfile, ServerProfile};
        use crate::sim::balancer::BalancerKind;
        use crate::sim::batching::{
            BatchLatencyCurve, BatchingMode, ContinuousBatchConfig, PricingMode,
        };
        use crate::sim::engine::{Scenario, SimConfig};
        use crate::sim::event_queue::EventQueueKind;
        use crate::sim::fleet::{
            run_fleet, DisaggSpec, FleetConfig, MigrationTargeting, PoolRole, ShardFault,
        };
        use crate::sim::kv::KvConfig;
        use crate::trace::generator::{Arrival, WorkloadSpec};

        let mut migrated_total = 0usize;
        let mut handoff_total = 0usize;
        let mut requeued_total = 0usize;
        let mut continuous_total = 0usize;
        let mut paged_total = 0usize;
        let mut kv_activity_total = 0usize;
        let mut parity_total = 0usize;
        let mut multizone_total = 0usize;
        let mut repriced_total = 0usize;
        check(
            "fleet-outage-migration-integrity",
            default_cases().clamp(16, 256),
            |r| {
                let k = 1 + r.below(4) as usize;
                let balancers = BalancerKind::all();
                let balancer = balancers[r.below(balancers.len() as u64) as usize];
                let targeting = if r.chance(0.5) {
                    MigrationTargeting::ShardTargeted
                } else {
                    MigrationTargeting::BaseEndpoint
                };
                let frac = r.f64();
                let dead = r.below(k as u64) as usize;
                let slots = 1 + r.below(2) as usize;
                let bscale = r.f64() * 1.5;
                let fault = r.chance(0.3);
                // Batching-mode axis (mode, budget, pages, curve, cache):
                // a third of the storms run slot-legacy, a third
                // continuous (budgets down to 16 tokens/tick force real
                // token queueing), a third paged KV with page pools
                // small enough (24..72 pages at 16-token blocks) that
                // decode growth trips memory-pressure preemption and an
                // outage hits streams with in-flight KV. The curve mix
                // includes steep slowdowns so batch sizes shifting
                // mid-decode stress the §4.3 buffer sizing.
                let batching = (
                    r.below(3) as u8,
                    16 + r.below(241) as u32,
                    24 + r.below(49) as usize,
                    r.below(3) as u8,
                    r.chance(0.5),
                );
                // A third of the storms double as event-queue parity
                // cases (wheel vs heap, byte-for-byte).
                let heap_check = r.chance(1.0 / 3.0);
                // Repricing axis: half the storms run iteration-level
                // batch repricing, so every invariant above is also
                // exercised against the piecewise re-stamped timelines.
                let repriced = r.chance(0.5);
                // Zone-partition axis: replicate the storm fleet into
                // Z zones and check the merge contract.
                let zones = 1 + r.below(3) as usize;
                // P/D-disaggregation axis: a third of the K ≥ 2 storms
                // split the same K shards into a random prefill/decode
                // partition (Some(p) ⇒ p prefill + k−p decode), so the
                // outage can land on either pool.
                let disagg = if k >= 2 && r.chance(1.0 / 3.0) {
                    Some(1 + r.below(k as u64 - 1) as usize)
                } else {
                    None
                };
                let seed = r.next_u64();
                (
                    k, balancer, targeting, frac, dead, slots, bscale, fault, batching,
                    (heap_check, repriced, zones, disagg), seed,
                )
            },
            |&(
                k,
                balancer,
                targeting,
                frac,
                dead,
                slots,
                bscale,
                fault,
                batching,
                (heap_check, repriced, zones, disagg),
                seed,
            )| {
                let mut cfg = SimConfig {
                    seed,
                    ..Default::default()
                };
                cfg.migration.buffer_scale = bscale;
                let sc = Scenario::new(
                    ServerProfile::deepseek_v25(),
                    DeviceProfile::xiaomi14_qwen0b5(),
                    Constraint::Device,
                    cfg,
                );
                // ~1.3× overload of the K-shard fleet, so the dead
                // shard's queue is non-trivial at any outage time.
                let gap = 1.0 / (0.9 * k as f64);
                let trace = WorkloadSpec {
                    arrival: Arrival::Fixed { gap },
                    ..WorkloadSpec::alpaca(50)
                }
                .generate(seed ^ 0x57012);
                let span = gap * 49.0;
                let mut fleet = FleetConfig::sharded(k, slots, balancer)
                    .with_migration_targeting(targeting)
                    .with_outage(frac * span, dead);
                let (mode, budget, pages, curve_sel, cache) = batching;
                let curve = match curve_sel {
                    0 => BatchLatencyCurve::Flat,
                    1 => BatchLatencyCurve::Linear { alpha: 0.3 },
                    _ => BatchLatencyCurve::Knee { knee: 4, alpha: 0.5 },
                };
                match mode {
                    1 => {
                        fleet = fleet.with_batching(BatchingMode::Continuous(
                            ContinuousBatchConfig {
                                prefill_tokens_per_tick: budget,
                                tick_interval: 0.25,
                                max_batch: None,
                                curve,
                            },
                        ));
                        continuous_total += 1;
                    }
                    2 => {
                        fleet = fleet.with_kv(KvConfig {
                            pages,
                            block_tokens: 16,
                            chunk_tokens: budget,
                            tick_interval: 0.25,
                            prefix_caching: cache,
                            curve,
                            ..KvConfig::default()
                        });
                        paged_total += 1;
                    }
                    _ => {}
                }
                if repriced && mode != 0 {
                    fleet = fleet.with_pricing(PricingMode::IterationLevel);
                    repriced_total += 1;
                }
                if fault {
                    fleet = fleet.with_shard_fault(
                        dead,
                        ShardFault {
                            spike_prob: 0.3,
                            spike_scale: 8.0,
                        },
                    );
                }
                if let Some(p) = disagg {
                    fleet = fleet.with_disagg(DisaggSpec::split(p, k - p));
                }
                let policy = Policy::simple(PolicyKind::StochD, 0.9, true);
                let out = run_fleet(&sc, &trace, &policy, &fleet);
                if heap_check {
                    let on_heap = run_fleet(
                        &sc,
                        &trace,
                        &policy,
                        &fleet.clone().with_event_queue(EventQueueKind::Heap),
                    );
                    crate::prop_assert!(
                        out.records == on_heap.records,
                        "wheel and heap backends popped different request trajectories"
                    );
                    crate::prop_assert!(
                        format!("{:?}", out.load) == format!("{:?}", on_heap.load),
                        "wheel and heap backends diverged in the load report"
                    );
                    parity_total += 1;
                }
                crate::prop_assert!(
                    out.records.len() == trace.len(),
                    "liveness: {} of {} requests resolved",
                    out.records.len(),
                    trace.len()
                );
                requeued_total += out.load.outage_requeues;
                for rec in &out.records {
                    if rec.migrated {
                        migrated_total += 1;
                    }
                    crate::prop_assert!(rec.ttft > 0.0, "req {}: ttft {} <= 0", rec.id, rec.ttft);
                    crate::prop_assert!(
                        rec.tbts.len() as u32 + 1 == rec.output_len,
                        "req {}: gap in stream — {} tbts for {} tokens",
                        rec.id,
                        rec.tbts.len(),
                        rec.output_len
                    );
                    crate::prop_assert!(
                        rec.tbts.iter().all(|&t| t > 0.0),
                        "req {}: order violated (non-positive perceived gap)",
                        rec.id
                    );
                    let decoded = rec.cost.server_decode_tokens + rec.cost.device_decode_tokens;
                    crate::prop_assert!(
                        decoded == rec.output_len as u64,
                        "req {}: duplicate/lost decode tokens — {decoded} vs {}",
                        rec.id,
                        rec.output_len
                    );
                }
                // Failure bookkeeping: the outage fired at most once, the
                // dead shard retires at most once, shard-seconds do not
                // leak past the per-shard lifetimes.
                crate::prop_assert!(
                    out.load.outage_count() <= 1,
                    "outage fired {} times",
                    out.load.outage_count()
                );
                for s in 0..out.load.shards.len() {
                    crate::prop_assert!(
                        out.load.retire_count(s) <= 1,
                        "shard {s} retired {} times",
                        out.load.retire_count(s)
                    );
                }
                let lifetimes: f64 = out.load.shards.iter().map(|s| s.lifetime_seconds).sum();
                crate::prop_assert!(
                    (out.load.shard_seconds - lifetimes).abs() < 1e-9,
                    "shard-seconds leak: {} vs {}",
                    out.load.shard_seconds,
                    lifetimes
                );
                let booked: usize = out.load.shards.iter().map(|s| s.migrated_in).sum();
                crate::prop_assert!(
                    booked == out.load.migration_targeted,
                    "booking mismatch: {booked} vs {}",
                    out.load.migration_targeted
                );
                // P/D-disaggregation axis: the handoff ledger balances
                // (every counted handoff landed on exactly one decode
                // target) and stays provably zero without a spec.
                let handed: usize = out.load.shards.iter().map(|s| s.handoff_in).sum();
                if let Some(p) = disagg {
                    crate::prop_assert!(
                        handed == out.load.handoff_count,
                        "handoff ledger mismatch: {handed} landed vs {} counted",
                        out.load.handoff_count
                    );
                    crate::prop_assert!(
                        out.load.shards[..p].iter().all(|s| s.handoff_in == 0),
                        "a handoff landed on a prefill shard"
                    );
                    crate::prop_assert!(
                        (out.load.handoff_count == 0) == (out.load.kv_transfer_seconds == 0.0),
                        "transfer seconds and handoff count must move together: {} for {}",
                        out.load.kv_transfer_seconds,
                        out.load.handoff_count
                    );
                    handoff_total += out.load.handoff_count;
                } else {
                    crate::prop_assert!(
                        out.load.handoff_count == 0
                            && handed == 0
                            && out.load.kv_transfer_seconds == 0.0
                            && out.load.handoff_fallbacks == 0
                            && out.load.shards.iter().all(|s| s.role == PoolRole::Unified),
                        "handoff telemetry must stay zero outside disaggregation"
                    );
                }
                // Accounting sweep invariants: no double releases
                // anywhere, and continuous-batching telemetry is
                // internally consistent.
                crate::prop_assert!(
                    out.load.release_underflows == 0,
                    "{} pool release underflows (double release)",
                    out.load.release_underflows
                );
                if mode != 0 {
                    let util = out.load.token_budget_utilization();
                    crate::prop_assert!(
                        matches!(util, Some(u) if u >= 0.0 && u.is_finite()),
                        "token utilization must be defined and finite: {util:?}"
                    );
                } else {
                    crate::prop_assert!(
                        out.load.batch_timeline.is_empty(),
                        "slot-legacy runs must record no batch timeline"
                    );
                }
                // KV-axis invariants: paged telemetry is internally
                // consistent, and no KV state leaks into slot/continuous
                // runs (the subsystem is inert unless selected).
                if mode == 2 {
                    kv_activity_total +=
                        out.load.kv_preemptions + out.load.kv_forced_reprefills;
                    crate::prop_assert!(
                        out.load.prefix_hits <= out.load.prefix_lookups,
                        "prefix hits ({}) exceed lookups ({})",
                        out.load.prefix_hits,
                        out.load.prefix_lookups
                    );
                    crate::prop_assert!(
                        out.load.shards.iter().all(|s| s.kv_pages_total > 0),
                        "paged shards must report their page pool"
                    );
                } else {
                    crate::prop_assert!(
                        out.load.prefix_lookups == 0
                            && out.load.kv_preemptions == 0
                            && out.load.kv_forced_reprefills == 0
                            && out.load.shards.iter().all(|s| s.kv_pages_total == 0),
                        "KV telemetry must stay zero outside paged mode"
                    );
                }
                // Repricing-axis inertness: join-time runs and
                // slot-legacy runs (where iteration-level pricing is a
                // declared no-op) must never touch the reprice counters.
                if !repriced || mode == 0 {
                    crate::prop_assert!(
                        out.load.reprice_events == 0
                            && out.load.reprice_stretch_seconds == 0.0
                            && out.load.reprice_shrink_seconds == 0.0,
                        "reprice telemetry must stay zero when repricing is off \
                         (repriced={repriced}, mode={mode}): {} events",
                        out.load.reprice_events
                    );
                }
                // Zone-partition leg: Z copies of the same storm fleet.
                let zoned_cfg = crate::sim::zones::ZonedFleetConfig::uniform(zones, fleet.clone());
                let zout = crate::sim::zones::run_zoned_fleet(&sc, &trace, &policy, &zoned_cfg);
                if zones == 1 {
                    crate::prop_assert!(
                        zout.merged.records == out.records
                            && format!("{:?}", zout.merged.load) == format!("{:?}", out.load),
                        "Z=1 zoned run diverged from run_fleet"
                    );
                } else {
                    multizone_total += 1;
                }
                crate::prop_assert!(
                    zout.merged.records.len() == trace.len(),
                    "zoned liveness: {} of {} requests resolved under Z={zones}",
                    zout.merged.records.len(),
                    trace.len()
                );
                for rec in &zout.merged.records {
                    crate::prop_assert!(
                        rec.tbts.len() as u32 + 1 == rec.output_len
                            && rec.tbts.iter().all(|&t| t > 0.0),
                        "req {}: merged stream integrity broke under Z={zones}",
                        rec.id
                    );
                }
                // Merge decomposition: the folded report's additive
                // fields are exactly the sums over `zone_loads`.
                let ev_sum: u64 = zout.zone_loads.iter().map(|l| l.events_processed).sum();
                let busy_sum: f64 = zout.zone_loads.iter().map(|l| l.server_busy_seconds).sum();
                let ss_sum: f64 = zout.zone_loads.iter().map(|l| l.shard_seconds).sum();
                let ru_sum: usize = zout.zone_loads.iter().map(|l| l.release_underflows).sum();
                crate::prop_assert!(
                    zout.merged.load.events_processed == ev_sum
                        && (zout.merged.load.server_busy_seconds - busy_sum).abs() < 1e-9
                        && (zout.merged.load.shard_seconds - ss_sum).abs() < 1e-9
                        && zout.merged.load.release_underflows == ru_sum,
                    "zoned load report does not decompose as the sum of its zones (Z={zones})"
                );
                let replay = crate::sim::zones::run_zoned_fleet(&sc, &trace, &policy, &zoned_cfg);
                crate::prop_assert!(
                    replay.merged.records == zout.merged.records
                        && format!("{:?}", replay.merged.load)
                            == format!("{:?}", zout.merged.load),
                    "zoned storm is not bit-reproducible (Z={zones})"
                );
                Ok(())
            },
        );
        assert!(migrated_total > 0, "property never exercised a migration");
        assert!(requeued_total > 0, "property never exercised an outage re-queue");
        assert!(
            continuous_total > 0,
            "property never exercised continuous batching"
        );
        assert!(paged_total > 0, "property never exercised paged KV");
        assert!(
            kv_activity_total > 0,
            "property never exercised KV preemption or forced re-prefill"
        );
        assert!(
            parity_total > 0,
            "property never exercised the wheel/heap backend parity check"
        );
        assert!(
            multizone_total > 0,
            "property never exercised a multi-zone partition"
        );
        assert!(
            repriced_total > 0,
            "property never exercised iteration-level repricing"
        );
        assert!(
            handoff_total > 0,
            "property never exercised a prefill→decode handoff"
        );
    }

    /// The full randomized storm grid (slow tier): every (K, balancer,
    /// targeting) combination with denser traces and both outage timing
    /// extremes, plus a bit-reproducibility check per cell.
    #[test]
    #[ignore = "exhaustive storm grid; run with --ignored or the slow-tests CI job"]
    fn prop_fleet_migration_storm_full_grid() {
        use crate::coordinator::policy::{Policy, PolicyKind};
        use crate::cost::unified::Constraint;
        use crate::profiles::{DeviceProfile, ServerProfile};
        use crate::sim::balancer::BalancerKind;
        use crate::sim::batching::{BatchingMode, ContinuousBatchConfig};
        use crate::sim::engine::{Scenario, SimConfig};
        use crate::sim::fleet::{run_fleet, FleetConfig, MigrationTargeting};
        use crate::sim::kv::KvConfig;
        use crate::trace::generator::{Arrival, WorkloadSpec};

        let sc = Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Device,
            SimConfig {
                seed: 4242,
                ..Default::default()
            },
        );
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        let batchings = [
            BatchingMode::SlotLegacy,
            BatchingMode::Continuous(ContinuousBatchConfig::default()),
            BatchingMode::PagedKv(KvConfig {
                pages: 48,
                ..KvConfig::default()
            }),
        ];
        for k in [2usize, 4, 6] {
            let gap = 1.0 / (0.9 * k as f64);
            let trace = WorkloadSpec {
                arrival: Arrival::Fixed { gap },
                ..WorkloadSpec::alpaca(200)
            }
            .generate(777 ^ k as u64);
            let span = gap * 199.0;
            for balancer in BalancerKind::all() {
                for targeting in [
                    MigrationTargeting::BaseEndpoint,
                    MigrationTargeting::ShardTargeted,
                ] {
                    for batching in batchings {
                        for frac in [0.1, 0.5, 0.9] {
                            let fleet = FleetConfig::sharded(k, 1, balancer)
                                .with_migration_targeting(targeting)
                                .with_batching(batching)
                                .with_outage(frac * span, k - 1);
                            let a = run_fleet(&sc, &trace, &policy, &fleet);
                            assert_eq!(a.records.len(), trace.len());
                            for rec in &a.records {
                                assert_eq!(rec.tbts.len() as u32 + 1, rec.output_len);
                                assert!(rec.tbts.iter().all(|&t| t > 0.0));
                                assert_eq!(
                                    rec.cost.server_decode_tokens
                                        + rec.cost.device_decode_tokens,
                                    rec.output_len as u64
                                );
                            }
                            assert_eq!(a.load.release_underflows, 0);
                            let b = run_fleet(&sc, &trace, &policy, &fleet);
                            assert_eq!(
                                a.records, b.records,
                                "{k}/{balancer}/{targeting}/{batching}/{frac}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0usize;
        check(
            "addition-commutes",
            64,
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            8,
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn prop_assert_macro() {
        check(
            "macro-works",
            16,
            |r| r.f64(),
            |&x| {
                prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
                Ok(())
            },
        );
    }
}
