//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random inputs; on failure it reports
//! the failing case number and seed so the case can be replayed
//! deterministically. Shrinking is out of scope — failures carry the full
//! generated input via `Debug` formatting instead.

use crate::util::rng::Rng;

/// Number of cases per property (override with DISCO_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("DISCO_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` over `cases` inputs drawn by `gen` from a seeded RNG.
/// Panics (test failure) with seed + case context when the property fails.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = 0xD15C0u64;
    for case in 0..cases {
        let mut rng =
            Rng::new(base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0usize;
        check(
            "addition-commutes",
            64,
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            8,
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn prop_assert_macro() {
        check(
            "macro-works",
            16,
            |r| r.f64(),
            |&x| {
                prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
                Ok(())
            },
        );
    }
}
