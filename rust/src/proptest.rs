//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random inputs; on failure it reports
//! the failing case number and seed so the case can be replayed
//! deterministically. Shrinking is out of scope — failures carry the full
//! generated input via `Debug` formatting instead.

use crate::util::rng::Rng;

/// Number of cases per property (override with DISCO_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("DISCO_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` over `cases` inputs drawn by `gen` from a seeded RNG.
/// Panics (test failure) with seed + case context when the property fails.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = 0xD15C0u64;
    for case in 0..cases {
        let mut rng =
            Rng::new(base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15)));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The migration invariant (§4.3): under *arbitrary* handoff timing —
    /// any migration point, any target warm-up, any source/target pacing —
    /// the delivered token stream has no gaps, no duplicates, and
    /// preserves order. In the timeline representation that means: the
    /// delivery schedule emits exactly one read time per generated token
    /// (count preserved ⇒ no gaps/duplicates), read times are monotone
    /// (order preserved across the handoff boundary), nothing is shown
    /// before it is generated, and pacing never beats the consumption
    /// rate.
    #[test]
    fn prop_migrated_stream_no_gaps_no_dups_order_preserved() {
        check(
            "migration-stream-integrity",
            256,
            |r| {
                let n = 2 + r.below(200) as usize;
                let ttft = 0.02 + r.f64();
                let r_c = 1.0 + r.f64() * 9.0;
                // Source stream up to a random handoff index m ∈ [1, n).
                let m = 1 + r.below(n as u64 - 1) as usize;
                let mut gen = Vec::with_capacity(n);
                gen.push(ttft);
                for _ in 1..m {
                    let g = r.f64() * 0.4;
                    gen.push(gen.last().unwrap() + g);
                }
                // Handoff: the target re-prefills for t_m (arbitrary, up
                // to several consumption intervals), then paces the tail.
                let t_m = r.f64() * 3.0;
                gen.push(gen.last().unwrap() + t_m);
                for _ in (m + 1)..n {
                    let g = r.f64() * 0.4;
                    gen.push(gen.last().unwrap() + g);
                }
                (gen, r_c)
            },
            |(gen, r_c)| {
                let d = crate::sim::delivery::smooth(gen, *r_c);
                prop_assert!(
                    d.read_times.len() == gen.len(),
                    "token count changed across handoff: {} generated, {} delivered",
                    gen.len(),
                    d.read_times.len()
                );
                prop_assert!(
                    d.tbts.len() + 1 == gen.len(),
                    "perceived-gap count mismatch: {} tbts for {} tokens",
                    d.tbts.len(),
                    gen.len()
                );
                let step = 1.0 / r_c;
                for i in 1..d.read_times.len() {
                    prop_assert!(
                        d.read_times[i] >= d.read_times[i - 1],
                        "order violated at {i}"
                    );
                    prop_assert!(
                        d.read_times[i] + 1e-9 >= gen[i],
                        "token {i} delivered before generated"
                    );
                    prop_assert!(
                        d.read_times[i] + 1e-9 >= d.read_times[i - 1] + step,
                        "pacing beats consumption rate at {i}"
                    );
                    prop_assert!(d.tbts[i - 1] > 0.0, "non-positive perceived gap at {i}");
                }
                Ok(())
            },
        );
    }

    /// The same invariant end-to-end: run a migration-heavy scenario and
    /// check every record's stream accounting survives the handoff.
    #[test]
    fn prop_engine_migration_preserves_stream_accounting() {
        use crate::coordinator::policy::{Policy, PolicyKind};
        use crate::cost::unified::Constraint;
        use crate::profiles::{DeviceProfile, ServerProfile};
        use crate::sim::engine::{Scenario, SimConfig};
        use crate::trace::generator::WorkloadSpec;

        let sc = Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::pixel7pro_bloom1b1(),
            Constraint::Device,
            SimConfig {
                seed: 99,
                ..Default::default()
            },
        );
        let mut migrated_total = 0usize;
        check(
            "engine-migration-stream",
            16,
            |r| r.next_u64(),
            |&seed| {
                let trace = WorkloadSpec::alpaca(60).generate(seed);
                let ecdf = sc.profile_server_ttft(400, seed);
                let policy =
                    Policy::plan(PolicyKind::DiscoD, 0.7, true, &ecdf, &trace.prompt_lens());
                for rec in sc.run(&trace, &policy) {
                    if rec.migrated {
                        migrated_total += 1;
                    }
                    prop_assert!(
                        rec.tbts.len() as u32 == rec.output_len - 1,
                        "stream count broke for request {}",
                        rec.id
                    );
                    let decoded =
                        rec.cost.server_decode_tokens + rec.cost.device_decode_tokens;
                    prop_assert!(
                        decoded == rec.output_len as u64,
                        "decode conservation broke: {decoded} vs {}",
                        rec.output_len
                    );
                    prop_assert!(
                        rec.tbts.iter().all(|&t| t > 0.0),
                        "non-positive perceived gap in request {}",
                        rec.id
                    );
                }
                Ok(())
            },
        );
        assert!(migrated_total > 0, "property never exercised a migration");
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0usize;
        check(
            "addition-commutes",
            64,
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                n += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            8,
            |r| r.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn prop_assert_macro() {
        check(
            "macro-works",
            16,
            |r| r.f64(),
            |&x| {
                prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
                Ok(())
            },
        );
    }
}
