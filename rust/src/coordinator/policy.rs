//! Dispatch policies: DiSCo and the paper's baselines (§5.1).
//!
//! * `ServerOnly` — all requests on the server (the vLLM baseline);
//! * `DeviceOnly` — all requests on the device (the llama.cpp baseline);
//! * `StochS` / `StochD` — stochastic dispatching that caps the
//!   constrained endpoint's budget by routing a Bernoulli(b) coin flip;
//! * `DiscoS` / `DiscoD` — the paper's cost-aware planners (Algorithms
//!   2–3), optionally with token-level migration.

use crate::coordinator::dispatch::{
    Decision, DeviceConstrainedPlan, ServerConstrainedPlan, SmoothDevicePlan,
};
use crate::cost::unified::Constraint;
use crate::stats::ecdf::Ecdf;
use crate::util::rng::Rng;

/// Policy family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    ServerOnly,
    DeviceOnly,
    StochS,
    StochD,
    DiscoS,
    DiscoD,
    /// Eq. 1–2's smooth β-interpolated wait variant (ablation).
    DiscoDSmooth,
}

impl PolicyKind {
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::ServerOnly => "vLLM (server-only)",
            PolicyKind::DeviceOnly => "llama.cpp (device-only)",
            PolicyKind::StochS => "Stoch-S",
            PolicyKind::StochD => "Stoch-D",
            PolicyKind::DiscoS => "DiSCo-S",
            PolicyKind::DiscoD => "DiSCo-D",
            PolicyKind::DiscoDSmooth => "DiSCo-D (smooth)",
        }
    }

    /// Which endpoint this policy treats as budget-constrained.
    pub fn constraint(&self) -> Option<Constraint> {
        match self {
            PolicyKind::StochS | PolicyKind::DiscoS => Some(Constraint::Server),
            PolicyKind::StochD | PolicyKind::DiscoD | PolicyKind::DiscoDSmooth => {
                Some(Constraint::Device)
            }
            _ => None,
        }
    }
}

/// A ready-to-run policy (planning already done).
#[derive(Clone, Debug)]
pub struct Policy {
    pub kind: PolicyKind,
    /// Budget ratio b ∈ [0,1] (meaning depends on the constraint).
    pub b: f64,
    /// Whether the migration controller may act during decode.
    pub migration: bool,
    device_plan: Option<DeviceConstrainedPlan>,
    server_plan: Option<ServerConstrainedPlan>,
    smooth_plan: Option<SmoothDevicePlan>,
}

/// Tail-protection reservation α (§4.2 Phase 1). The paper leaves the
/// value free; 0.05 reserves the P95+ tail.
pub const DEFAULT_ALPHA: f64 = 0.05;

impl Policy {
    /// Plan a policy from profiling data: the server TTFT ECDF and an
    /// empirical prompt-length sample (uses [`DEFAULT_ALPHA`]).
    pub fn plan(
        kind: PolicyKind,
        b: f64,
        migration: bool,
        server_ttft: &Ecdf,
        lengths: &[u32],
    ) -> Policy {
        Self::plan_with_alpha(kind, b, migration, server_ttft, lengths, DEFAULT_ALPHA)
    }

    /// Like [`Policy::plan`] with an explicit tail-protection α
    /// (exercised by the `abl-alpha` ablation).
    pub fn plan_with_alpha(
        kind: PolicyKind,
        b: f64,
        migration: bool,
        server_ttft: &Ecdf,
        lengths: &[u32],
        alpha: f64,
    ) -> Policy {
        let (device_plan, server_plan, smooth_plan) = match kind {
            PolicyKind::DiscoD => (
                Some(DeviceConstrainedPlan::plan(
                    server_ttft,
                    lengths,
                    b,
                    alpha.min(b),
                )),
                None,
                None,
            ),
            PolicyKind::DiscoDSmooth => (
                None,
                None,
                Some(DeviceConstrainedPlan::plan_smooth(
                    server_ttft,
                    lengths,
                    b,
                    alpha.min(b),
                )),
            ),
            PolicyKind::DiscoS => (None, Some(ServerConstrainedPlan::plan(lengths, b)), None),
            _ => (None, None, None),
        };
        Policy {
            kind,
            b,
            migration,
            device_plan,
            server_plan,
            smooth_plan,
        }
    }

    /// Simple policies that need no planning.
    pub fn simple(kind: PolicyKind, b: f64, migration: bool) -> Policy {
        assert!(
            !matches!(
                kind,
                PolicyKind::DiscoS | PolicyKind::DiscoD | PolicyKind::DiscoDSmooth
            ),
            "DiSCo policies need Policy::plan"
        );
        Policy {
            kind,
            b,
            migration,
            device_plan: None,
            server_plan: None,
            smooth_plan: None,
        }
    }

    /// Per-request dispatch decision.
    pub fn decide(&self, prompt_len: u32, rng: &mut Rng) -> Decision {
        match self.kind {
            PolicyKind::ServerOnly => Decision::ServerOnly,
            PolicyKind::DeviceOnly => Decision::DeviceOnly,
            // Stoch-S: spend the server budget on a random b-fraction of
            // requests (device covers the rest alone).
            PolicyKind::StochS => {
                if rng.chance(self.b) {
                    Decision::Both { device_wait: 0.0 }
                } else {
                    Decision::DeviceOnly
                }
            }
            // Stoch-D: spend the device budget on a random b-fraction
            // (server covers the rest alone).
            PolicyKind::StochD => {
                if rng.chance(self.b) {
                    Decision::Both { device_wait: 0.0 }
                } else {
                    Decision::ServerOnly
                }
            }
            PolicyKind::DiscoS => self.server_plan.as_ref().unwrap().decide(prompt_len),
            PolicyKind::DiscoD => self.device_plan.as_ref().unwrap().decide(prompt_len),
            PolicyKind::DiscoDSmooth => self.smooth_plan.as_ref().unwrap().decide(prompt_len),
        }
    }

    /// The constraint this policy manages (None for unconstrained
    /// baselines, which also never migrate).
    pub fn constraint(&self) -> Option<Constraint> {
        self.kind.constraint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiles::server::ServerProfile;

    fn fixtures() -> (Ecdf, Vec<u32>) {
        let p = ServerProfile::command();
        let mut rng = Rng::new(33);
        let ecdf = Ecdf::new((0..2000).map(|_| p.sample_ttft(&mut rng)).collect());
        let lens: Vec<u32> = (0..2000)
            .map(|_| (rng.lognormal(3.0, 0.9).round() as u32).clamp(4, 1024))
            .collect();
        (ecdf, lens)
    }

    #[test]
    fn baselines_are_degenerate() {
        let mut rng = Rng::new(1);
        let s = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let d = Policy::simple(PolicyKind::DeviceOnly, 1.0, false);
        for l in [4u32, 100, 1000] {
            assert_eq!(s.decide(l, &mut rng), Decision::ServerOnly);
            assert_eq!(d.decide(l, &mut rng), Decision::DeviceOnly);
        }
    }

    #[test]
    fn stoch_policies_hit_budget_fraction() {
        let mut rng = Rng::new(2);
        let b = 0.3;
        let ps = Policy::simple(PolicyKind::StochS, b, false);
        let n = 20_000;
        let server_used = (0..n)
            .filter(|_| ps.decide(50, &mut rng).uses_server())
            .count();
        let frac = server_used as f64 / n as f64;
        assert!((frac - b).abs() < 0.02, "frac={frac}");

        let pd = Policy::simple(PolicyKind::StochD, b, false);
        let device_used = (0..n)
            .filter(|_| pd.decide(50, &mut rng).uses_device())
            .count();
        let frac = device_used as f64 / n as f64;
        assert!((frac - b).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn disco_policies_plan_and_decide() {
        let (ecdf, lens) = fixtures();
        let mut rng = Rng::new(3);
        let ds = Policy::plan(PolicyKind::DiscoS, 0.5, true, &ecdf, &lens);
        // Short prompt → device-only; long → both.
        assert_eq!(ds.decide(4, &mut rng), Decision::DeviceOnly);
        assert_eq!(
            ds.decide(1024, &mut rng),
            Decision::Both { device_wait: 0.0 }
        );
        let dd = Policy::plan(PolicyKind::DiscoD, 0.5, true, &ecdf, &lens);
        match dd.decide(1024, &mut rng) {
            Decision::Both { device_wait } => assert!(device_wait > 0.0),
            other => panic!("expected Both, got {other:?}"),
        }
        match dd.decide(4, &mut rng) {
            Decision::Both { device_wait } => assert_eq!(device_wait, 0.0),
            other => panic!("expected Both, got {other:?}"),
        }
    }

    #[test]
    fn constraint_mapping() {
        assert_eq!(PolicyKind::DiscoS.constraint(), Some(Constraint::Server));
        assert_eq!(PolicyKind::StochD.constraint(), Some(Constraint::Device));
        assert_eq!(PolicyKind::ServerOnly.constraint(), None);
        for k in [
            PolicyKind::ServerOnly,
            PolicyKind::DeviceOnly,
            PolicyKind::StochS,
            PolicyKind::StochD,
            PolicyKind::DiscoS,
            PolicyKind::DiscoD,
        ] {
            assert!(!k.label().is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "need Policy::plan")]
    fn disco_simple_panics() {
        Policy::simple(PolicyKind::DiscoS, 0.5, false);
    }
}
