//! Token-level migration control (§4.3).
//!
//! When both endpoints were dispatched and the prefill winner is the
//! *cost-constrained* endpoint, decode can be handed to the cheaper
//! endpoint. Migration transfers token IDs only — no KV cache (§4.3's two
//! practical reasons) — so the target must re-prefill prompt + generated
//! prefix. The controller fires only when projected savings exceed that
//! overhead (Eq. 4), and delays the handoff until a token buffer of
//! `B = r_c × t_m` (Eq. 5) can mask the target's warm-up.

use crate::cost::unified::{Constraint, CostParams};
use crate::endpoint::EndpointKind;

/// Migration tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct MigrationConfig {
    pub enabled: bool,
    /// Human consumption rate r_c, tokens/s (§2.2: reading ≈ 4–5 tok/s).
    pub consumption_rate: f64,
    /// Network round-trip added to the target warm-up estimate (seconds).
    pub rtt: f64,
    /// Ablation knob: scales Eq. 5's buffer (1.0 = paper's sizing;
    /// <1 under-buffers and should delay tokens — `disco exp abl-buffer`).
    pub buffer_scale: f64,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig {
            enabled: true,
            consumption_rate: 5.0,
            rtt: 0.05,
            buffer_scale: 1.0,
        }
    }
}

/// A concrete migration decision for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationPlan {
    /// Buffer size B in tokens (Eq. 5).
    pub buffer_tokens: u32,
    /// Estimated migration overhead t_m (target re-prefill + RTT).
    pub t_m_est: f64,
    /// Endpoint generation moves to.
    pub target: EndpointKind,
}

/// Stateless migration planner.
#[derive(Clone, Copy, Debug)]
pub struct MigrationPlanner {
    pub config: MigrationConfig,
    pub costs: CostParams,
}

impl MigrationPlanner {
    pub fn new(config: MigrationConfig, costs: CostParams) -> Self {
        MigrationPlanner { config, costs }
    }

    /// The migration direction for a given winner, if any: generation
    /// moves *off* the constrained endpoint (§4.3 "the constrained
    /// endpoint may win the prefill phase but incur higher decode costs").
    pub fn direction(&self, constraint: Constraint, winner: EndpointKind) -> Option<EndpointKind> {
        match (constraint, winner) {
            (Constraint::Device, EndpointKind::Device) => Some(EndpointKind::Server),
            (Constraint::Server, EndpointKind::Server) => Some(EndpointKind::Device),
            _ => None,
        }
    }

    /// Eq. 4 trigger: projected decode-cost savings on the remaining
    /// tokens must exceed the target's re-prefill cost over
    /// `reprefill_len = prompt + generated prefix` tokens.
    pub fn worth_migrating(
        &self,
        target: EndpointKind,
        remaining_tokens: u32,
        reprefill_len: u32,
    ) -> bool {
        let savings = self.costs.decode_delta() * remaining_tokens as f64;
        let overhead = match target {
            EndpointKind::Server => self.costs.server_prefill * reprefill_len as f64,
            EndpointKind::Device => self.costs.device_prefill * reprefill_len as f64,
        };
        savings > overhead
    }

    /// Predicted admission delay on a server shard carrying
    /// `outstanding_secs` of estimated service with `slots` concurrent
    /// admissions (`None` = unlimited, no queueing): the same
    /// work-over-capacity predictor the TTFT-target autoscaler uses.
    /// Folded into the re-prefill warm-up estimate when migration is
    /// shard-targeted, so a loaded target inflates `t_m` — and thus the
    /// Eq. 5 buffer — instead of being silently free.
    ///
    /// Audit note (PR-5 bugfix sweep): callers must pass *queued-ahead*
    /// work only. A migrated stream books via the batch-join overflow
    /// path, so a shard with a spare real slot admits it instantly —
    /// the fleet's `reprefill_queue_delay` short-circuits that case to
    /// 0 and excludes the migrating stream's own booking from
    /// `outstanding_secs` (the off-by-one that used to price the stream
    /// into its own queue; pinned by the idle-fleet byte-parity test in
    /// `sim::fleet`).
    pub fn queue_delay_estimate(&self, outstanding_secs: f64, slots: Option<usize>) -> f64 {
        match slots {
            Some(c) if c > 0 => (outstanding_secs / c as f64).max(0.0),
            Some(_) => outstanding_secs.max(0.0),
            None => 0.0,
        }
    }

    /// Token-denominated admission-delay predictor for continuous
    /// batching: the queued prompt-token backlog over the shard's
    /// admission token rate (`prefill_tokens_per_tick / tick_interval`).
    /// A non-positive rate (defensive; normalized configs cannot produce
    /// one) predicts no delay rather than an infinite one.
    pub fn queue_delay_estimate_tokens(&self, queued_tokens: u64, tokens_per_sec: f64) -> f64 {
        if tokens_per_sec > 0.0 {
            queued_tokens as f64 / tokens_per_sec
        } else {
            0.0
        }
    }

    /// The token predictor re-derived from the *live* batch: under
    /// iteration-level pricing a shard's scheduler iterations stretch
    /// with the current batch's slowdown, so the same token backlog
    /// drains `batch_slowdown` times slower than the nominal admission
    /// rate predicts. `batch_slowdown` is
    /// `BatchLatencyCurve::slowdown(current batch)` — exactly 1.0 under
    /// `Flat` curves and single-stream batches, making this identical
    /// to [`Self::queue_delay_estimate_tokens`] there (the join-time
    /// path keeps calling the unscaled predictor, so legacy estimates
    /// never chase live batches they do not price).
    pub fn queue_delay_estimate_tokens_at_batch(
        &self,
        queued_tokens: u64,
        tokens_per_sec: f64,
        batch_slowdown: f64,
    ) -> f64 {
        self.queue_delay_estimate_tokens(queued_tokens, tokens_per_sec) * batch_slowdown.max(1.0)
    }

    /// Build the concrete plan (Eq. 5). `target_expected_ttft` is the
    /// target endpoint's expected warm-up for re-prefilling
    /// `reprefill_len` tokens.
    pub fn plan(
        &self,
        constraint: Constraint,
        winner: EndpointKind,
        remaining_tokens: u32,
        reprefill_len: u32,
        target_expected_ttft: f64,
    ) -> Option<MigrationPlan> {
        if !self.config.enabled || remaining_tokens == 0 {
            return None;
        }
        let target = self.direction(constraint, winner)?;
        if !self.worth_migrating(target, remaining_tokens, reprefill_len) {
            return None;
        }
        let t_m = target_expected_ttft + self.config.rtt;
        let buffer =
            (self.config.consumption_rate * t_m * self.config.buffer_scale).ceil() as u32;
        Some(MigrationPlan {
            buffer_tokens: buffer.max(1),
            t_m_est: t_m,
            target,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device_constrained_costs() -> CostParams {
        // λ = 5 $/PFLOP-style scenario: device ≫ server.
        CostParams {
            server_prefill: 1.5e-7,
            server_decode: 6.0e-7,
            device_prefill: 4.0e-6,
            device_decode: 4.1e-6,
        }
    }

    fn server_constrained_costs() -> CostParams {
        CostParams {
            server_prefill: 1.5e-7,
            server_decode: 6.0e-7,
            device_prefill: 1.2e-7,
            device_decode: 8.0e-8,
        }
    }

    #[test]
    fn direction_moves_off_constrained_endpoint() {
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        assert_eq!(
            p.direction(Constraint::Device, EndpointKind::Device),
            Some(EndpointKind::Server)
        );
        assert_eq!(p.direction(Constraint::Device, EndpointKind::Server), None);
        assert_eq!(
            p.direction(Constraint::Server, EndpointKind::Server),
            Some(EndpointKind::Device)
        );
        assert_eq!(p.direction(Constraint::Server, EndpointKind::Device), None);
    }

    #[test]
    fn eq4_trigger_scales_with_remaining() {
        let p = MigrationPlanner::new(MigrationConfig::default(), server_constrained_costs());
        // Δc_decode = 5.2e-7; device re-prefill 1.2e-7/token.
        // remaining=100, reprefill=50: savings 5.2e-5 > 6e-6 → migrate.
        assert!(p.worth_migrating(EndpointKind::Device, 100, 50));
        // remaining=5, reprefill=500: savings 2.6e-6 < 6e-5 → don't.
        assert!(!p.worth_migrating(EndpointKind::Device, 5, 500));
    }

    #[test]
    fn buffer_follows_eq5() {
        let cfg = MigrationConfig {
            enabled: true,
            consumption_rate: 5.0,
            rtt: 0.1,
            buffer_scale: 1.0,
        };
        let p = MigrationPlanner::new(cfg, device_constrained_costs());
        let plan = p
            .plan(Constraint::Device, EndpointKind::Device, 100, 40, 0.5)
            .expect("should migrate");
        // t_m = 0.5 + 0.1 = 0.6 → B = ceil(5 × 0.6) = 3.
        assert_eq!(plan.target, EndpointKind::Server);
        assert!((plan.t_m_est - 0.6).abs() < 1e-12);
        assert_eq!(plan.buffer_tokens, 3);
    }

    #[test]
    fn disabled_or_empty_never_migrates() {
        let cfg = MigrationConfig {
            enabled: false,
            ..Default::default()
        };
        let p = MigrationPlanner::new(cfg, device_constrained_costs());
        assert!(p
            .plan(Constraint::Device, EndpointKind::Device, 100, 40, 0.5)
            .is_none());
        let p2 = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        assert!(p2
            .plan(Constraint::Device, EndpointKind::Device, 0, 40, 0.5)
            .is_none());
    }

    #[test]
    fn wrong_direction_winner_never_migrates() {
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        // Server won in a device-constrained setting: server decode is the
        // cheap side already — no migration.
        assert!(p
            .plan(Constraint::Device, EndpointKind::Server, 100, 40, 0.5)
            .is_none());
    }

    #[test]
    fn buffer_scale_shrinks_buffer() {
        let mk = |scale| MigrationConfig {
            buffer_scale: scale,
            ..Default::default()
        };
        let costs = device_constrained_costs();
        let full = MigrationPlanner::new(mk(1.0), costs)
            .plan(Constraint::Device, EndpointKind::Device, 100, 40, 2.0)
            .unwrap();
        let half = MigrationPlanner::new(mk(0.5), costs)
            .plan(Constraint::Device, EndpointKind::Device, 100, 40, 2.0)
            .unwrap();
        assert!(half.buffer_tokens < full.buffer_tokens);
        let none = MigrationPlanner::new(mk(0.0), costs)
            .plan(Constraint::Device, EndpointKind::Device, 100, 40, 2.0)
            .unwrap();
        assert_eq!(none.buffer_tokens, 1); // floor of 1 token
    }

    /// The shard-aware queue-delay predictor degrades gracefully:
    /// unlimited pools add no queueing, zero-slot pools fall back to the
    /// raw backlog, and folding a loaded shard's prediction into
    /// `target_expected_ttft` strictly inflates the Eq. 5 buffer
    /// relative to an idle one (a loaded migration target must buffer
    /// more) — the composition the fleet's shard-targeted resolve step
    /// performs through the target endpoint's `extra_rtt`.
    #[test]
    fn queue_delay_estimate_inflates_buffer_with_load() {
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        assert_eq!(p.queue_delay_estimate(3.0, None), 0.0);
        assert_eq!(p.queue_delay_estimate(3.0, Some(2)), 1.5);
        assert_eq!(p.queue_delay_estimate(3.0, Some(0)), 3.0);
        assert_eq!(p.queue_delay_estimate(-1.0, Some(2)), 0.0);
        let idle = 0.4 + p.queue_delay_estimate(0.0, Some(1));
        let loaded = 0.4 + p.queue_delay_estimate(4.0, Some(1));
        assert!((idle - 0.4).abs() < 1e-12);
        assert!((loaded - 4.4).abs() < 1e-12);
        let plan_idle = p
            .plan(Constraint::Device, EndpointKind::Device, 200, 40, idle)
            .expect("idle target should migrate");
        let plan_loaded = p
            .plan(Constraint::Device, EndpointKind::Device, 200, 40, loaded)
            .expect("loaded target should still migrate when Eq. 4 holds");
        assert!(
            plan_loaded.buffer_tokens > plan_idle.buffer_tokens,
            "loaded target must buffer more: {} vs {}",
            plan_loaded.buffer_tokens,
            plan_idle.buffer_tokens
        );
        assert!(plan_loaded.t_m_est > plan_idle.t_m_est);
    }

    /// The token-denominated predictor (continuous batching): backlog
    /// over admission rate, with the same buffer-inflation composition
    /// as the slot predictor, and a defensive zero on degenerate rates.
    #[test]
    fn queue_delay_estimate_tokens_prices_backlog() {
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        assert_eq!(p.queue_delay_estimate_tokens(0, 512.0), 0.0);
        assert_eq!(p.queue_delay_estimate_tokens(1024, 512.0), 2.0);
        assert_eq!(p.queue_delay_estimate_tokens(1024, 0.0), 0.0);
        assert_eq!(p.queue_delay_estimate_tokens(1024, -1.0), 0.0);
        let idle = 0.4 + p.queue_delay_estimate_tokens(0, 256.0);
        let loaded = 0.4 + p.queue_delay_estimate_tokens(2048, 256.0);
        let plan_idle = p
            .plan(Constraint::Device, EndpointKind::Device, 200, 40, idle)
            .expect("idle target should migrate");
        let plan_loaded = p
            .plan(Constraint::Device, EndpointKind::Device, 200, 40, loaded)
            .expect("loaded target should still migrate");
        assert!(
            plan_loaded.buffer_tokens > plan_idle.buffer_tokens,
            "a deep token backlog must inflate the Eq. 5 buffer"
        );
    }

    /// The live-batch predictor scales the token backlog by the current
    /// slowdown (iteration-level pricing), degrades to the nominal
    /// predictor at slowdown 1.0 — bit-for-bit, which is what keeps
    /// `Flat`-curve repriced runs byte-identical — and clamps sub-1.0
    /// slowdowns (a curve can never make draining faster than nominal).
    #[test]
    fn queue_delay_estimate_at_batch_scales_with_live_slowdown() {
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        let nominal = p.queue_delay_estimate_tokens(1024, 512.0);
        assert_eq!(
            p.queue_delay_estimate_tokens_at_batch(1024, 512.0, 1.0),
            nominal
        );
        assert_eq!(
            p.queue_delay_estimate_tokens_at_batch(1024, 512.0, 2.5),
            nominal * 2.5
        );
        assert_eq!(
            p.queue_delay_estimate_tokens_at_batch(1024, 512.0, 0.25),
            nominal,
            "sub-1.0 slowdowns clamp to the nominal rate"
        );
        assert_eq!(p.queue_delay_estimate_tokens_at_batch(0, 512.0, 3.0), 0.0);
    }

    #[test]
    fn prop_buffer_masks_overhead() {
        // Property: B/r_c ≥ t_m, i.e. a full buffer covers the warm-up.
        let p = MigrationPlanner::new(MigrationConfig::default(), device_constrained_costs());
        crate::proptest::check(
            "buffer-masks-overhead",
            128,
            |r| (r.f64() * 5.0, 1 + r.below(500) as u32, 1 + r.below(500) as u32),
            |&(ttft, remaining, reprefill)| {
                if let Some(plan) =
                    p.plan(Constraint::Device, EndpointKind::Device, remaining, reprefill, ttft)
                {
                    let cover = plan.buffer_tokens as f64 / p.config.consumption_rate;
                    crate::prop_assert!(
                        cover + 1e-9 >= plan.t_m_est,
                        "buffer {} covers only {cover:.3}s of t_m {:.3}s",
                        plan.buffer_tokens,
                        plan.t_m_est
                    );
                }
                Ok(())
            },
        );
    }
}
