//! Cost-aware dispatch control (§4.2, Algorithms 1–3).
//!
//! Planning happens offline from profiled distributions: the server TTFT
//! ECDF `F` (length-independent, §3) and the empirical prompt-length
//! distribution `p(l)`. Per-request decisions are then O(log n) lookups.
//!
//! * **Device-constrained** (Algorithm 2): every request goes to the
//!   server; the device additionally starts after a per-length wait
//!   `w(l)`, chosen so expected device prefill spend stays within
//!   `b·E[l]` while reserving a tail-protection share `α` (Eq. 1–2).
//! * **Server-constrained** (Algorithm 3): prompts shorter than a length
//!   threshold `l_th` run device-only; longer prompts run on both
//!   endpoints concurrently (Eq. 3).

use crate::stats::ecdf::Ecdf;

/// Per-request dispatch decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Run only on the device (llama.cpp-style).
    DeviceOnly,
    /// Run only on the server (vLLM-style).
    ServerOnly,
    /// Start the server immediately; start the device after `device_wait`
    /// seconds unless the server produced a token first. `device_wait`
    /// may be 0 (fully concurrent) or `f64::INFINITY` (never — degenerate
    /// but representable).
    Both { device_wait: f64 },
}

impl Decision {
    pub fn uses_server(&self) -> bool {
        matches!(self, Decision::ServerOnly | Decision::Both { .. })
    }
    pub fn uses_device(&self) -> bool {
        !matches!(self, Decision::ServerOnly)
    }
}

// ---------------------------------------------------------------------
// Device-constrained scheduling (Algorithm 2)
// ---------------------------------------------------------------------

/// Wait-time plan for device-constrained scenarios.
///
/// Greedy construction over ascending prompt lengths yields a prefix
/// structure: lengths ≤ `l_immediate` start the device at once (w = 0),
/// one boundary length gets a partial wait `w_star`, and everything
/// longer waits the tail-protection wait `w_tail`.
#[derive(Clone, Debug)]
pub struct DeviceConstrainedPlan {
    pub b: f64,
    pub alpha: f64,
    /// Maximum wait, F⁻¹(1 − min(α, b)) — Phase 1 tail protection.
    pub w_tail: f64,
    /// Largest prompt length whose wait is 0 (None if none).
    pub l_immediate: Option<u32>,
    /// The single partially-funded boundary length and its wait.
    pub boundary: Option<(u32, f64)>,
}

impl DeviceConstrainedPlan {
    /// Algorithm 2 over an empirical length sample and a server-TTFT ECDF.
    ///
    /// `b` is the budget ratio (expected device prefill tokens / expected
    /// prompt tokens); `alpha` the tail-protection reservation.
    pub fn plan(server_ttft: &Ecdf, lengths: &[u32], b: f64, alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&b), "budget b must be in [0,1]");
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        assert!(!lengths.is_empty(), "need a profiled length sample");

        // Phase 1: maximum wait time for tail protection.
        let reserve = alpha.min(b);
        let w_tail = if reserve <= 0.0 {
            // No budget at all: the device never starts.
            f64::INFINITY
        } else {
            server_ttft.quantile(1.0 - reserve)
        };

        let mut plan = DeviceConstrainedPlan {
            b,
            alpha,
            w_tail,
            l_immediate: None,
            boundary: None,
        };
        if b <= alpha || !w_tail.is_finite() {
            // Entire budget consumed by tail protection.
            return plan;
        }

        // Phase 2: spend (b − α)·E[l] granting w = 0 to short prompts.
        let n = lengths.len() as f64;
        let mean_len = lengths.iter().map(|&l| l as f64).sum::<f64>() / n;
        let mut available = (b - alpha) * mean_len;

        // Distinct lengths ascending with empirical probabilities.
        let mut sorted = lengths.to_vec();
        sorted.sort_unstable();
        let mut i = 0usize;
        let f_wtail = server_ttft.cdf(w_tail);
        while i < sorted.len() {
            let l = sorted[i];
            let mut count = 0usize;
            while i < sorted.len() && sorted[i] == l {
                count += 1;
                i += 1;
            }
            let p = count as f64 / n;
            // Upgrading this length from w_tail to 0 raises device-run
            // probability from (1 − F(w_tail)) = α to 1.
            let length_cost = p * l as f64 * (1.0 - reserve);
            if available >= length_cost {
                plan.l_immediate = Some(l);
                available -= length_cost;
            } else {
                // Partially fund this boundary length: find w* with
                // p·l·(F(w_tail) − F(w*)) = available.
                let target_f = f_wtail - available / (p * l as f64);
                let w_star = if target_f <= 0.0 {
                    0.0
                } else {
                    server_ttft.quantile(target_f)
                };
                plan.boundary = Some((l, w_star.min(w_tail)));
                break;
            }
        }
        plan
    }

    /// Eq. 1–2's *smooth* variant: instead of Algorithm 2's stepwise
    /// waits, lengths above the immediate threshold get `w(l) =
    /// min(β·l, w_tail)` with β solved numerically so the expected spend
    /// (Eq. 2) exhausts the remaining budget. Exposed as an ablation
    /// against the stepwise plan (`disco exp abl-smooth`).
    pub fn plan_smooth(
        server_ttft: &Ecdf,
        lengths: &[u32],
        b: f64,
        alpha: f64,
    ) -> SmoothDevicePlan {
        let base = Self::plan(server_ttft, lengths, b, alpha);
        let l_th = base.l_immediate.unwrap_or(0);
        if b <= alpha || !base.w_tail.is_finite() {
            return SmoothDevicePlan {
                base,
                l_th,
                beta: f64::INFINITY,
            };
        }
        // Spend(β) = Σ_{l ≤ l_th} l + Σ_{l > l_th} (1 − F(min(βl, w_tail)))·l,
        // monotone nonincreasing in β → bisection to hit b·E[l]·n.
        let n = lengths.len() as f64;
        let target = b * lengths.iter().map(|&l| l as f64).sum::<f64>() / n;
        let spend = |beta: f64| -> f64 {
            lengths
                .iter()
                .map(|&l| {
                    if l <= l_th {
                        l as f64
                    } else {
                        let w = (beta * l as f64).min(base.w_tail);
                        (1.0 - server_ttft.cdf(w)) * l as f64
                    }
                })
                .sum::<f64>()
                / n
        };
        // β = w_tail saturates every l ≥ 1 at w_tail, so it brackets.
        let (mut lo, mut hi) = (0.0f64, base.w_tail.max(1e-9));
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if spend(mid) > target {
                lo = mid; // spending too much → wait longer (bigger β)
            } else {
                hi = mid;
            }
        }
        SmoothDevicePlan {
            base,
            l_th,
            beta: 0.5 * (lo + hi),
        }
    }

    /// Per-request wait time w(l) (Eq. 1's implementable form).
    pub fn wait_for(&self, prompt_len: u32) -> f64 {
        if let Some(l_imm) = self.l_immediate {
            if prompt_len <= l_imm {
                return 0.0;
            }
        }
        if let Some((l_b, w_star)) = self.boundary {
            if prompt_len == l_b {
                return w_star;
            }
        }
        self.w_tail
    }

    /// The dispatch decision: server always starts; device after w(l).
    pub fn decide(&self, prompt_len: u32) -> Decision {
        Decision::Both {
            device_wait: self.wait_for(prompt_len),
        }
    }

    /// Expected device prefill spend as a fraction of E[l] under this plan
    /// — used by tests to verify the budget constraint E[I_d·l] ≤ b·E[l].
    pub fn expected_spend_fraction(&self, server_ttft: &Ecdf, lengths: &[u32]) -> f64 {
        let n = lengths.len() as f64;
        let mean_len = lengths.iter().map(|&l| l as f64).sum::<f64>() / n;
        let spend: f64 = lengths
            .iter()
            .map(|&l| {
                let w = self.wait_for(l);
                let p_run = if w.is_infinite() {
                    0.0
                } else {
                    1.0 - server_ttft.cdf(w)
                };
                p_run * l as f64
            })
            .sum::<f64>()
            / n;
        spend / mean_len
    }
}

/// The Eq. 1–2 smooth wait plan (see [`DeviceConstrainedPlan::plan_smooth`]).
#[derive(Clone, Debug)]
pub struct SmoothDevicePlan {
    pub base: DeviceConstrainedPlan,
    /// Immediate-start threshold l_th.
    pub l_th: u32,
    /// Slope β of Eq. 1.
    pub beta: f64,
}

impl SmoothDevicePlan {
    /// Eq. 1: w(l) = 0 below l_th, else min(β·l, w_tail).
    pub fn wait_for(&self, prompt_len: u32) -> f64 {
        if prompt_len <= self.l_th {
            0.0
        } else if self.beta.is_infinite() {
            self.base.w_tail
        } else {
            (self.beta * prompt_len as f64).min(self.base.w_tail)
        }
    }

    pub fn decide(&self, prompt_len: u32) -> Decision {
        Decision::Both {
            device_wait: self.wait_for(prompt_len),
        }
    }

    /// Expected device prefill spend fraction under this plan.
    pub fn expected_spend_fraction(&self, server_ttft: &Ecdf, lengths: &[u32]) -> f64 {
        let n = lengths.len() as f64;
        let mean_len = lengths.iter().map(|&l| l as f64).sum::<f64>() / n;
        let spend: f64 = lengths
            .iter()
            .map(|&l| {
                let w = self.wait_for(l);
                if w.is_infinite() {
                    0.0
                } else {
                    (1.0 - server_ttft.cdf(w)) * l as f64
                }
            })
            .sum::<f64>()
            / n;
        spend / mean_len
    }
}

// ---------------------------------------------------------------------
// Server-constrained scheduling (Algorithm 3)
// ---------------------------------------------------------------------

/// Length-threshold plan for server-constrained scenarios (Eq. 3).
#[derive(Clone, Debug)]
pub struct ServerConstrainedPlan {
    pub b: f64,
    /// Prompts strictly shorter run device-only; the rest run both.
    pub l_threshold: u32,
}

impl ServerConstrainedPlan {
    /// Eq. 3: choose l_th so prompts below it carry (1−b) of expected
    /// prompt tokens — the device-only share.
    pub fn plan(lengths: &[u32], b: f64) -> Self {
        assert!((0.0..=1.0).contains(&b), "budget b must be in [0,1]");
        assert!(!lengths.is_empty(), "need a profiled length sample");
        let mut sorted = lengths.to_vec();
        sorted.sort_unstable();
        let total: f64 = sorted.iter().map(|&l| l as f64).sum();
        let target = (1.0 - b) * total;
        let mut cum = 0.0;
        for &l in &sorted {
            if cum >= target {
                return ServerConstrainedPlan { b, l_threshold: l };
            }
            cum += l as f64;
        }
        // Budget 0 (or rounding): everything device-only.
        ServerConstrainedPlan {
            b,
            l_threshold: u32::MAX,
        }
    }

    /// Algorithm 3's execution map.
    pub fn decide(&self, prompt_len: u32) -> Decision {
        if prompt_len < self.l_threshold {
            Decision::DeviceOnly
        } else {
            Decision::Both { device_wait: 0.0 }
        }
    }

    /// Expected server prefill spend fraction (≤ b up to discretization).
    pub fn expected_spend_fraction(&self, lengths: &[u32]) -> f64 {
        let total: f64 = lengths.iter().map(|&l| l as f64).sum();
        let server: f64 = lengths
            .iter()
            .filter(|&&l| l >= self.l_threshold)
            .map(|&l| l as f64)
            .sum();
        server / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiles::server::ServerProfile;
    use crate::util::rng::Rng;

    fn server_ecdf(seed: u64) -> Ecdf {
        let p = ServerProfile::gpt4o_mini();
        let mut rng = Rng::new(seed);
        Ecdf::new((0..3000).map(|_| p.sample_ttft(&mut rng)).collect())
    }

    fn sample_lengths(seed: u64, n: usize) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (rng.lognormal(3.0, 0.9).round() as u32).clamp(4, 1024))
            .collect()
    }

    // ---- device-constrained (Algorithm 2) ----

    #[test]
    fn device_plan_respects_budget() {
        let f = server_ecdf(1);
        let lens = sample_lengths(2, 4000);
        for b in [0.05, 0.2, 0.5, 0.8, 1.0] {
            let plan = DeviceConstrainedPlan::plan(&f, &lens, b, 0.05);
            let spend = plan.expected_spend_fraction(&f, &lens);
            assert!(
                spend <= b + 0.02,
                "b={b}: spend fraction {spend:.3} exceeds budget"
            );
        }
    }

    #[test]
    fn device_plan_spends_most_of_budget() {
        // The plan should not be overly conservative: spend ≥ 80% of b.
        let f = server_ecdf(3);
        let lens = sample_lengths(4, 4000);
        for b in [0.2, 0.5, 0.8] {
            let plan = DeviceConstrainedPlan::plan(&f, &lens, b, 0.05);
            let spend = plan.expected_spend_fraction(&f, &lens);
            assert!(spend >= 0.8 * b, "b={b}: spend {spend:.3} too conservative");
        }
    }

    #[test]
    fn device_plan_short_prompts_start_immediately() {
        let f = server_ecdf(5);
        let lens = sample_lengths(6, 4000);
        let plan = DeviceConstrainedPlan::plan(&f, &lens, 0.5, 0.05);
        let l_imm = plan.l_immediate.expect("b=0.5 funds some immediate starts");
        assert_eq!(plan.wait_for(l_imm), 0.0);
        assert_eq!(plan.wait_for(4), 0.0);
        // A very long prompt waits w_tail.
        assert_eq!(plan.wait_for(100_000), plan.w_tail);
        assert!(plan.w_tail.is_finite());
    }

    #[test]
    fn device_plan_tail_protection_quantile() {
        let f = server_ecdf(7);
        let lens = sample_lengths(8, 2000);
        let alpha = 0.1;
        let plan = DeviceConstrainedPlan::plan(&f, &lens, 0.5, alpha);
        // w_tail = F⁻¹(1 − α): server exceeds it with probability α.
        assert!((f.survival(plan.w_tail) - alpha).abs() < 0.02);
    }

    #[test]
    fn device_plan_zero_budget_never_runs_device() {
        let f = server_ecdf(9);
        let lens = sample_lengths(10, 500);
        let plan = DeviceConstrainedPlan::plan(&f, &lens, 0.0, 0.1);
        assert!(plan.w_tail.is_infinite());
        assert_eq!(plan.expected_spend_fraction(&f, &lens), 0.0);
    }

    #[test]
    fn device_plan_b_below_alpha_all_wait_tail() {
        let f = server_ecdf(11);
        let lens = sample_lengths(12, 500);
        let plan = DeviceConstrainedPlan::plan(&f, &lens, 0.05, 0.2);
        assert!(plan.l_immediate.is_none());
        assert!(plan.boundary.is_none());
        // Reserve is min(α,b) = b: survival(w_tail) = b.
        assert!((f.survival(plan.w_tail) - 0.05).abs() < 0.02);
    }

    #[test]
    fn device_plan_monotone_waits() {
        // w(l) must be nondecreasing in l (short prompts never wait more).
        let f = server_ecdf(13);
        let lens = sample_lengths(14, 3000);
        let plan = DeviceConstrainedPlan::plan(&f, &lens, 0.4, 0.05);
        let mut last = 0.0;
        for l in (4..1024).step_by(7) {
            let w = plan.wait_for(l);
            assert!(w + 1e-12 >= last, "w({l})={w} < w(prev)={last}");
            last = w;
        }
    }

    // ---- smooth Eq. 1–2 variant ----

    #[test]
    fn smooth_plan_respects_budget_and_monotone() {
        let f = server_ecdf(23);
        let lens = sample_lengths(24, 4000);
        for b in [0.2, 0.5, 0.8] {
            let plan = DeviceConstrainedPlan::plan_smooth(&f, &lens, b, 0.05);
            let spend = plan.expected_spend_fraction(&f, &lens);
            assert!(spend <= b + 0.03, "b={b}: smooth spend {spend:.3}");
            assert!(spend >= 0.7 * b, "b={b}: smooth spend {spend:.3} too low");
            // Waits nondecreasing in l, capped at w_tail.
            let mut last = 0.0;
            for l in (1..2048).step_by(13) {
                let w = plan.wait_for(l);
                assert!(w + 1e-12 >= last);
                assert!(w <= plan.base.w_tail + 1e-12);
                last = w;
            }
        }
    }

    #[test]
    fn smooth_plan_zero_budget_degenerates() {
        let f = server_ecdf(25);
        let lens = sample_lengths(26, 500);
        let plan = DeviceConstrainedPlan::plan_smooth(&f, &lens, 0.0, 0.1);
        assert!(plan.beta.is_infinite());
        assert_eq!(plan.expected_spend_fraction(&f, &lens), 0.0);
    }

    #[test]
    fn smooth_and_stepwise_spend_similarly() {
        let f = server_ecdf(27);
        let lens = sample_lengths(28, 3000);
        let b = 0.5;
        let step = DeviceConstrainedPlan::plan(&f, &lens, b, 0.05);
        let smooth = DeviceConstrainedPlan::plan_smooth(&f, &lens, b, 0.05);
        let s1 = step.expected_spend_fraction(&f, &lens);
        let s2 = smooth.expected_spend_fraction(&f, &lens);
        assert!((s1 - s2).abs() < 0.1, "step {s1:.3} vs smooth {s2:.3}");
    }

    // ---- server-constrained (Algorithm 3) ----

    #[test]
    fn server_plan_respects_budget() {
        let lens = sample_lengths(15, 4000);
        for b in [0.0, 0.1, 0.3, 0.6, 0.9, 1.0] {
            let plan = ServerConstrainedPlan::plan(&lens, b);
            let spend = plan.expected_spend_fraction(&lens);
            assert!(spend <= b + 0.02, "b={b}: server share {spend:.3}");
            // And uses most of the budget (long prompts are coarse-grained,
            // so allow slack proportional to the largest prompt).
            if b > 0.1 {
                assert!(spend >= b - 0.1, "b={b}: spend {spend:.3} too low");
            }
        }
    }

    #[test]
    fn server_plan_threshold_split() {
        let lens = sample_lengths(17, 2000);
        let plan = ServerConstrainedPlan::plan(&lens, 0.5);
        assert_eq!(plan.decide(plan.l_threshold - 1), Decision::DeviceOnly);
        assert_eq!(
            plan.decide(plan.l_threshold),
            Decision::Both { device_wait: 0.0 }
        );
    }

    #[test]
    fn server_plan_extremes() {
        let lens = sample_lengths(19, 1000);
        // b=1: everything may use the server.
        let p1 = ServerConstrainedPlan::plan(&lens, 1.0);
        assert!(p1.l_threshold <= *lens.iter().min().unwrap());
        // b=0: nothing uses the server.
        let p0 = ServerConstrainedPlan::plan(&lens, 0.0);
        assert_eq!(p0.l_threshold, u32::MAX);
        assert_eq!(p0.expected_spend_fraction(&lens), 0.0);
    }

    #[test]
    fn decision_helpers() {
        assert!(Decision::ServerOnly.uses_server());
        assert!(!Decision::ServerOnly.uses_device());
        assert!(Decision::DeviceOnly.uses_device());
        assert!(!Decision::DeviceOnly.uses_server());
        let both = Decision::Both { device_wait: 1.0 };
        assert!(both.uses_server() && both.uses_device());
    }

    // ---- property tests ----

    #[test]
    fn prop_budget_invariant_holds_for_random_workloads() {
        let f = server_ecdf(21);
        crate::proptest::check(
            "dispatch-budget-invariant",
            crate::proptest::default_cases().min(64),
            |r| {
                let n = 200 + r.below(800) as usize;
                let median = 8.0 + r.f64() * 200.0;
                let sigma = 0.3 + r.f64() * 1.0;
                let lens: Vec<u32> = (0..n)
                    .map(|_| (r.lognormal(median.ln(), sigma).round() as u32).clamp(1, 4096))
                    .collect();
                let b = r.f64();
                let alpha = r.f64() * 0.3;
                (lens, b, alpha)
            },
            |(lens, b, alpha)| {
                let dplan = DeviceConstrainedPlan::plan(&f, lens, *b, *alpha);
                let dspend = dplan.expected_spend_fraction(&f, lens);
                crate::prop_assert!(
                    dspend <= b + 0.03,
                    "device spend {dspend:.3} > b {b:.3}"
                );
                let splan = ServerConstrainedPlan::plan(lens, *b);
                let sspend = splan.expected_spend_fraction(lens);
                crate::prop_assert!(
                    sspend <= b + 0.03,
                    "server spend {sspend:.3} > b {b:.3}"
                );
                Ok(())
            },
        );
    }
}
