//! The DiSCo coordinator — the paper's system contribution (§4).
//!
//! Two controllers cooperate per request:
//!
//! 1. the **dispatch controller** ([`dispatch`]) decides *where to start*
//!    token generation (device, server, or both with a device wait time),
//!    trading TTFT against the unified cost budget (Algorithms 1–3);
//! 2. the **migration controller** ([`migration`]) decides *where to
//!    finish* it, handing generation off mid-decode when the projected
//!    decode-cost savings exceed the re-prefill overhead (Eqs. 4–5),
//!    masked by a consumption-rate-aware token buffer.
//!
//! [`policy`] packages both behind one interface together with the
//! paper's baselines (ServerOnly/vLLM, DeviceOnly/llama.cpp, Stoch-S,
//! Stoch-D).

pub mod dispatch;
pub mod migration;
pub mod policy;

pub use dispatch::{Decision, DeviceConstrainedPlan, ServerConstrainedPlan};
pub use migration::{MigrationConfig, MigrationPlanner};
pub use policy::{Policy, PolicyKind};
