//! Response-quality model under migration (Appendix D, Figs. 8 & 10).
//!
//! Appendix D.1 proves the bound (Eq. 6): a migrated sequence's quality
//! lies between the two endpoints' individual qualities,
//! `min(Q_A, Q_B) ≤ Q_M ≤ max(Q_A, Q_B)`. The paper's Figure 8/10
//! evaluation (LLM judges are unreachable offline — see DESIGN.md) is
//! reproduced by the bound's implied model: migrated quality is a
//! position-weighted mixture of endpoint qualities plus per-judge
//! observation noise, clamped to the bound.

use crate::util::rng::Rng;

/// A model endpoint's intrinsic quality on a task family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelQuality {
    pub name: &'static str,
    /// Mean judge score on instruction following (1–10 scale; the paper
    /// observes 4–6 for 0.5B–7B models).
    pub instruct_score: f64,
    /// Mean ROUGE-1 on zho→eng translation (paper band: 0.23–0.26).
    pub rouge1: f64,
}

/// Qwen-2.5 model family qualities (calibrated to Appendix D's observed
/// ranges: larger models better, all within the reported bands).
pub fn qwen(size_b: f64) -> ModelQuality {
    // Smooth log-scaling through the reported 4–6 band.
    let instruct = 4.2 + 0.75 * (size_b.max(0.1)).ln_1p();
    let rouge = 0.232 + 0.012 * (size_b.max(0.1)).ln_1p();
    let name = match size_b {
        s if s < 1.0 => "Qwen-0.5B",
        s if s < 4.0 => "Qwen-3B",
        _ => "Qwen-7B",
    };
    ModelQuality {
        name,
        instruct_score: instruct.min(6.0),
        rouge1: rouge.min(0.26),
    }
}

/// An LLM judge with its own bias and dispersion.
#[derive(Clone, Copy, Debug)]
pub struct Judge {
    pub name: &'static str,
    pub bias: f64,
    pub noise: f64,
}

/// The paper's three judges (GPT-4o, Gemini-1.5-pro, Qwen-2.5-72b).
pub fn judges() -> [Judge; 3] {
    [
        Judge {
            name: "GPT-4o",
            bias: 0.0,
            noise: 0.25,
        },
        Judge {
            name: "Gemini1.5-pro",
            bias: -0.15,
            noise: 0.30,
        },
        Judge {
            name: "QWen2.5-72b",
            bias: 0.20,
            noise: 0.35,
        },
    ]
}

/// Eq. 6: clamp a migrated-sequence quality into the endpoint bound.
pub fn quality_bound(q_a: f64, q_b: f64, q_m: f64) -> f64 {
    q_m.clamp(q_a.min(q_b), q_a.max(q_b))
}

/// Expected quality of a sequence whose first `first_len` of `total_len`
/// tokens came from endpoint A and the rest from endpoint B — the
/// position-weighted mixture implied by the bound's derivation.
pub fn migrated_quality(q_a: f64, q_b: f64, first_len: u32, total_len: u32) -> f64 {
    assert!(total_len > 0);
    let w = (first_len.min(total_len)) as f64 / total_len as f64;
    let mixed = w * q_a + (1.0 - w) * q_b;
    quality_bound(q_a, q_b, mixed)
}

/// One judged observation of a migrated generation (Fig. 8 data point).
pub fn judge_score(
    judge: &Judge,
    q_a: f64,
    q_b: f64,
    first_len: u32,
    total_len: u32,
    rng: &mut Rng,
) -> f64 {
    let q = migrated_quality(q_a, q_b, first_len, total_len);
    (q + judge.bias + judge.noise * rng.normal()).clamp(1.0, 10.0)
}

/// ROUGE-1 observation for the translation task (Fig. 10 top panel).
pub fn rouge_score(
    q_a: &ModelQuality,
    q_b: &ModelQuality,
    first_len: u32,
    total_len: u32,
    rng: &mut Rng,
) -> f64 {
    let q = migrated_quality(q_a.rouge1, q_b.rouge1, first_len, total_len);
    (q + 0.004 * rng.normal()).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_clamps_both_sides() {
        assert_eq!(quality_bound(4.0, 6.0, 7.0), 6.0);
        assert_eq!(quality_bound(4.0, 6.0, 3.0), 4.0);
        assert_eq!(quality_bound(6.0, 4.0, 5.0), 5.0);
    }

    #[test]
    fn migrated_quality_endpoints() {
        // first_len = 0 ⇒ pure B; first_len = total ⇒ pure A.
        assert_eq!(migrated_quality(4.0, 6.0, 0, 100), 6.0);
        assert_eq!(migrated_quality(4.0, 6.0, 100, 100), 4.0);
        let mid = migrated_quality(4.0, 6.0, 50, 100);
        assert!((mid - 5.0).abs() < 1e-12);
    }

    #[test]
    fn prop_eq6_always_holds() {
        crate::proptest::check(
            "quality-bound-eq6",
            256,
            |r| {
                let qa = 1.0 + r.f64() * 9.0;
                let qb = 1.0 + r.f64() * 9.0;
                let total = 1 + r.below(256) as u32;
                let first = r.below(total as u64 + 1) as u32;
                (qa, qb, first, total)
            },
            |&(qa, qb, first, total)| {
                let qm = migrated_quality(qa, qb, first, total);
                crate::prop_assert!(
                    qm >= qa.min(qb) - 1e-12 && qm <= qa.max(qb) + 1e-12,
                    "Eq.6 violated: qa={qa} qb={qb} qm={qm}"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn qwen_family_monotone_in_size() {
        let q05 = qwen(0.5);
        let q3 = qwen(3.0);
        let q7 = qwen(7.0);
        assert!(q05.instruct_score < q3.instruct_score);
        assert!(q3.instruct_score < q7.instruct_score);
        // Paper's bands: scores in 4–6, ROUGE in 0.23–0.26.
        for q in [q05, q3, q7] {
            assert!((4.0..=6.0).contains(&q.instruct_score), "{q:?}");
            assert!((0.23..=0.26).contains(&q.rouge1), "{q:?}");
        }
    }

    #[test]
    fn judge_scores_stay_on_scale() {
        let mut rng = Rng::new(5);
        let [j1, _, _] = judges();
        for _ in 0..500 {
            let s = judge_score(&j1, 4.5, 5.5, 16, 256, &mut rng);
            assert!((1.0..=10.0).contains(&s));
        }
    }

    #[test]
    fn rouge_band_preserved() {
        let mut rng = Rng::new(6);
        let a = qwen(0.5);
        let b = qwen(7.0);
        for first in [0u32, 4, 16, 64, 256] {
            let s = rouge_score(&a, &b, first, 256, &mut rng);
            assert!((0.2..=0.28).contains(&s), "first={first} s={s}");
        }
    }
}
