//! Statistics substrate: descriptive stats, correlation, empirical CDFs,
//! and distribution fitting. Everything operates on `f64` slices and is
//! allocation-conscious — these routines sit on the dispatch hot path.

pub mod corr;
pub mod describe;
pub mod ecdf;
pub mod fit;

pub use corr::pearson;
pub use describe::{mean, percentile, std_dev, Summary};
pub use ecdf::Ecdf;
pub use fit::LogNormalFit;
