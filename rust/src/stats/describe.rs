//! Descriptive statistics: mean, variance, percentiles, summaries.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on sorted data (p in [0,100]).
/// Sorts a copy; for repeated queries use [`sorted_percentile`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted_percentile(&sorted, p)
}

/// Percentile on already-sorted data.
pub fn sorted_percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// One-pass summary of a sample, as reported in the paper's tables.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: sorted[0],
            p50: sorted_percentile(&sorted, 50.0),
            p90: sorted_percentile(&sorted, 90.0),
            p99: sorted_percentile(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // p clamped
        assert_eq!(percentile(&xs, 150.0), 4.0);
    }

    #[test]
    fn p99_of_uniform_sequence() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let p99 = percentile(&xs, 99.0);
        assert!((p99 - 989.01).abs() < 0.02, "p99={p99}");
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }
}
