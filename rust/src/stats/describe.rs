//! Descriptive statistics: mean, variance, percentiles, summaries.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on sorted data (p in [0,100]).
/// Sorts a copy; for repeated queries use [`sorted_percentile`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    sorted_percentile(&sorted, p)
}

/// Percentile on already-sorted data.
pub fn sorted_percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// One-pass summary of a sample, as reported in the paper's tables.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: sorted[0],
            p50: sorted_percentile(&sorted, 50.0),
            p90: sorted_percentile(&sorted, 90.0),
            p99: sorted_percentile(&sorted, 99.0),
            max: *sorted.last().unwrap(),
        }
    }

    /// Merge per-partition summaries into one population summary.
    ///
    /// `n`, `mean`, `min`, and `max` are exact; `std` pools the
    /// per-partition variances exactly (the parallel-variance identity
    /// with the n−1 sample denominator the rest of this module uses).
    /// Quantiles cannot be reconstructed from summaries alone — the raw
    /// samples are gone — so `p50`/`p90`/`p99` are the count-weighted
    /// means of the per-partition quantiles: exact when the partitions
    /// are identically distributed (the zoned-fleet use case, where a
    /// trace is round-robin split), an approximation otherwise.
    ///
    /// Merging a single summary returns it bit-for-bit (the identity),
    /// and empty partitions are skipped, so a Z=1 zoned run reports the
    /// same summaries as the unzoned fleet.
    pub fn merge(parts: &[Summary]) -> Summary {
        let live: Vec<&Summary> = parts.iter().filter(|s| s.n > 0).collect();
        if live.is_empty() {
            return Summary::of(&[]);
        }
        if live.len() == 1 {
            return live[0].clone();
        }
        let n: usize = live.iter().map(|s| s.n).sum();
        let nf = n as f64;
        let mean = live.iter().map(|s| s.mean * s.n as f64).sum::<f64>() / nf;
        // Pooled variance: total sum of squared deviations about the
        // grand mean = Σ [ (n_i − 1)·s_i² + n_i·(m_i − m)² ], then the
        // sample (n − 1) denominator.
        let std = if n < 2 {
            0.0
        } else {
            let ss: f64 = live
                .iter()
                .map(|s| {
                    let ni = s.n as f64;
                    let d = s.mean - mean;
                    (ni - 1.0) * s.std * s.std + ni * d * d
                })
                .sum();
            (ss.max(0.0) / (nf - 1.0)).sqrt()
        };
        let wq = |pick: fn(&Summary) -> f64| -> f64 {
            live.iter().map(|s| pick(s) * s.n as f64).sum::<f64>() / nf
        };
        Summary {
            n,
            mean,
            std,
            min: live.iter().map(|s| s.min).fold(f64::INFINITY, f64::min),
            p50: wq(|s| s.p50),
            p90: wq(|s| s.p90),
            p99: wq(|s| s.p99),
            max: live.iter().map(|s| s.max).fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // p clamped
        assert_eq!(percentile(&xs, 150.0), 4.0);
    }

    #[test]
    fn p99_of_uniform_sequence() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let p99 = percentile(&xs, 99.0);
        assert!((p99 - 989.01).abs() < 0.02, "p99={p99}");
    }

    #[test]
    fn summary_merge_single_is_identity_and_exact_fields_pool() {
        let xs: Vec<f64> = (1..=50).map(|i| i as f64 * 0.3).collect();
        let one = Summary::of(&xs);
        // Single-part merge is bit-identical (and empty parts are skipped).
        let merged = Summary::merge(&[one.clone()]);
        assert_eq!(format!("{one:?}"), format!("{merged:?}"));
        let merged = Summary::merge(&[Summary::of(&[]), one.clone(), Summary::of(&[])]);
        assert_eq!(format!("{one:?}"), format!("{merged:?}"));
        assert_eq!(Summary::merge(&[]).n, 0);

        // Split-vs-whole: n/mean/min/max exact, std pools exactly.
        let (a, b) = xs.split_at(17);
        let m = Summary::merge(&[Summary::of(a), Summary::of(b)]);
        let whole = Summary::of(&xs);
        assert_eq!(m.n, whole.n);
        assert!((m.mean - whole.mean).abs() < 1e-12);
        assert_eq!(m.min, whole.min);
        assert_eq!(m.max, whole.max);
        assert!((m.std - whole.std).abs() < 1e-9, "{} vs {}", m.std, whole.std);
        // Quantiles are a count-weighted approximation; stay ordered.
        assert!(m.min <= m.p50 && m.p50 <= m.p90 && m.p90 <= m.p99 && m.p99 <= m.max);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }
}
