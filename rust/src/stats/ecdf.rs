//! Empirical cumulative distribution function with inverse.
//!
//! The dispatch controller (§4.2) treats the server TTFT distribution as
//! a profiled empirical distribution: Algorithm 2 evaluates F(t) and
//! F⁻¹(q); Eq. 2's integral is solved numerically over the same samples.

/// ECDF over a sorted sample.
#[derive(Clone, Debug)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    /// Build from (possibly unsorted) samples. Panics on empty/NaN input.
    pub fn new(mut samples: Vec<f64>) -> Ecdf {
        assert!(!samples.is_empty(), "ECDF needs at least one sample");
        assert!(
            samples.iter().all(|x| x.is_finite()),
            "ECDF samples must be finite"
        );
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ecdf { sorted: samples }
    }

    pub fn n(&self) -> usize {
        self.sorted.len()
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// F(t) = P(X <= t), right-continuous step function.
    pub fn cdf(&self, t: f64) -> f64 {
        // partition_point: count of samples <= t.
        let count = self.sorted.partition_point(|&x| x <= t);
        count as f64 / self.sorted.len() as f64
    }

    /// F⁻¹(q): the q-quantile, with linear interpolation between order
    /// statistics (q in [0,1]).
    pub fn quantile(&self, q: f64) -> f64 {
        crate::stats::describe::sorted_percentile(&self.sorted, q.clamp(0.0, 1.0) * 100.0)
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        crate::stats::describe::mean(&self.sorted)
    }

    /// P(X > t) = 1 - F(t): the survival function used in Eq. 2.
    pub fn survival(&self, t: f64) -> f64 {
        1.0 - self.cdf(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ecdf4() -> Ecdf {
        Ecdf::new(vec![4.0, 1.0, 3.0, 2.0])
    }

    #[test]
    fn cdf_step_values() {
        let e = ecdf4();
        assert_eq!(e.cdf(0.5), 0.0);
        assert_eq!(e.cdf(1.0), 0.25);
        assert_eq!(e.cdf(2.5), 0.5);
        assert_eq!(e.cdf(4.0), 1.0);
        assert_eq!(e.cdf(9.0), 1.0);
    }

    #[test]
    fn quantile_interpolates() {
        let e = ecdf4();
        assert_eq!(e.quantile(0.0), 1.0);
        assert_eq!(e.quantile(1.0), 4.0);
        assert!((e.quantile(0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_cdf_roundtrip() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(2);
        let e = Ecdf::new((0..5000).map(|_| r.lognormal(0.0, 0.5)).collect());
        for q in [0.1, 0.5, 0.9, 0.99] {
            let t = e.quantile(q);
            assert!((e.cdf(t) - q).abs() < 0.01, "q={q} cdf={}", e.cdf(t));
        }
    }

    #[test]
    fn survival_complements_cdf() {
        let e = ecdf4();
        for t in [0.0, 1.5, 3.0, 5.0] {
            assert!((e.survival(t) + e.cdf(t) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Ecdf::new(vec![]);
    }

    #[test]
    fn quantile_single_sample_is_constant() {
        let e = Ecdf::new(vec![3.5]);
        for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
            assert_eq!(e.quantile(q), 3.5, "q={q}");
        }
        // Out-of-range q clamps rather than extrapolating.
        assert_eq!(e.quantile(-0.5), 3.5);
        assert_eq!(e.quantile(7.0), 3.5);
        assert_eq!(e.cdf(3.5), 1.0);
        assert_eq!(e.cdf(3.4999), 0.0);
        assert_eq!(e.survival(3.5), 0.0);
    }

    #[test]
    fn quantile_q0_q1_are_extremes() {
        let e = ecdf4();
        assert_eq!(e.quantile(0.0), e.min());
        assert_eq!(e.quantile(1.0), e.max());
        assert_eq!(e.quantile(-3.0), e.min());
        assert_eq!(e.quantile(42.0), e.max());
    }

    #[test]
    fn prop_quantile_extremes_and_monotonicity() {
        use crate::util::rng::Rng;
        crate::proptest::check(
            "ecdf-quantile-edges",
            64,
            |r| {
                let n = 1 + r.below(300) as usize;
                let mut rr = Rng::new(r.next_u64());
                (0..n).map(|_| rr.lognormal(0.0, 1.0)).collect::<Vec<f64>>()
            },
            |samples| {
                let e = Ecdf::new(samples.clone());
                crate::prop_assert!(e.quantile(0.0) == e.min(), "q=0 must be the min");
                crate::prop_assert!(e.quantile(1.0) == e.max(), "q=1 must be the max");
                let mut last = f64::NEG_INFINITY;
                for i in 0..=10 {
                    let q = i as f64 / 10.0;
                    let v = e.quantile(q);
                    crate::prop_assert!(v >= last, "quantile not monotone at q={q}");
                    last = v;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mean_min_max() {
        let e = ecdf4();
        assert_eq!(e.mean(), 2.5);
        assert_eq!(e.min(), 1.0);
        assert_eq!(e.max(), 4.0);
        assert_eq!(e.n(), 4);
    }
}
