//! Correlation measures. The paper's Table 1 reports the Pearson
//! coefficient between prompt length and TTFT for each deployment.

/// Pearson correlation coefficient of two equal-length samples.
/// Returns 0.0 when either sample is degenerate (zero variance or n < 2).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Ordinary least squares fit y = k·x + c. Returns (k, c).
/// Used to recover the device TTFT model from profiling samples (§3).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..xs.len() {
        sxy += (xs[i] - mx) * (ys[i] - my);
        sxx += (xs[i] - mx) * (xs[i] - mx);
    }
    if sxx == 0.0 {
        return (0.0, my);
    }
    let k = sxy / sxx;
    (k, my - k * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_positive() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_negative() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &ys) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_is_zero() {
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn independent_near_zero() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        let ys: Vec<f64> = (0..20_000).map(|_| r.f64()).collect();
        assert!(pearson(&xs, &ys).abs() < 0.03);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (k, c) = linear_fit(&xs, &ys);
        assert!((k - 3.0).abs() < 1e-9);
        assert!((c - 7.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_degenerate_x() {
        let (k, c) = linear_fit(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(k, 0.0);
        assert_eq!(c, 2.0);
    }
}
