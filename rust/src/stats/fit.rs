//! Distribution fitting. §5.3 of the paper fits log-normal distributions
//! to prompt lengths and TTFTs "by following the mean and standard
//! deviation of the logarithm" — this module implements exactly that.

use crate::util::rng::Rng;

/// Log-normal fit: (mu, sigma) of the underlying normal in log-space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogNormalFit {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormalFit {
    /// MLE fit from positive samples (non-positive samples are skipped).
    pub fn fit(samples: &[f64]) -> LogNormalFit {
        let logs: Vec<f64> = samples
            .iter()
            .copied()
            .filter(|&x| x > 0.0)
            .map(f64::ln)
            .collect();
        if logs.is_empty() {
            return LogNormalFit { mu: 0.0, sigma: 0.0 };
        }
        let mu = crate::stats::describe::mean(&logs);
        let sigma = if logs.len() < 2 {
            0.0
        } else {
            // MLE uses the population std (n denominator).
            let ss: f64 = logs.iter().map(|x| (x - mu) * (x - mu)).sum();
            (ss / logs.len() as f64).sqrt()
        };
        LogNormalFit { mu, sigma }
    }

    /// Distribution mean exp(mu + sigma^2/2).
    pub fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }

    /// Distribution median exp(mu).
    pub fn median(&self) -> f64 {
        self.mu.exp()
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(self.mu, self.sigma)
    }

    /// Draw n samples.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_parameters() {
        let mut r = Rng::new(71);
        let truth = LogNormalFit { mu: 1.2, sigma: 0.4 };
        let xs = truth.sample_n(&mut r, 100_000);
        let fit = LogNormalFit::fit(&xs);
        assert!((fit.mu - truth.mu).abs() < 0.01, "mu={}", fit.mu);
        assert!((fit.sigma - truth.sigma).abs() < 0.01, "sigma={}", fit.sigma);
    }

    #[test]
    fn mean_formula() {
        let f = LogNormalFit { mu: 0.0, sigma: 1.0 };
        assert!((f.mean() - (0.5f64).exp()).abs() < 1e-12);
        assert_eq!(f.median(), 1.0);
    }

    #[test]
    fn skips_nonpositive() {
        let fit = LogNormalFit::fit(&[-1.0, 0.0, 1.0, std::f64::consts::E]);
        assert!((fit.mu - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_fit_is_degenerate() {
        let fit = LogNormalFit::fit(&[]);
        assert_eq!(fit.mu, 0.0);
        assert_eq!(fit.sigma, 0.0);
    }
}
