//! Walk-forward evaluation with the paper's Table 5 metrics (MAPE, MAE).

use crate::predictor::Predictor;

/// Evaluation result for one (predictor, trace) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredEval {
    /// Mean absolute percentage error, percent.
    pub mape_pct: f64,
    /// Mean absolute error (seconds).
    pub mae: f64,
    pub n: usize,
}

/// Fit on the first `warmup` points, then predict each subsequent point
/// from the full preceding history (one-step-ahead walk-forward).
pub fn evaluate(p: &mut dyn Predictor, series: &[f64], warmup: usize) -> PredEval {
    assert!(warmup < series.len(), "warmup must leave evaluation points");
    p.fit(&series[..warmup]);
    let mut abs_err = 0.0;
    let mut pct_err = 0.0;
    let mut n = 0usize;
    for t in warmup..series.len() {
        let pred = p.predict_next(&series[..t]);
        let actual = series[t];
        abs_err += (pred - actual).abs();
        if actual.abs() > 1e-12 {
            pct_err += ((pred - actual) / actual).abs();
        }
        n += 1;
    }
    PredEval {
        mape_pct: pct_err / n as f64 * 100.0,
        mae: abs_err / n as f64,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::smoothing::MovingAverage;

    #[test]
    fn perfect_constant_series_zero_error() {
        let series = vec![2.0; 100];
        let mut p = MovingAverage::new(4);
        let e = evaluate(&mut p, &series, 50);
        assert!(e.mape_pct < 1e-9);
        assert!(e.mae < 1e-9);
        assert_eq!(e.n, 50);
    }

    #[test]
    fn noisy_series_nonzero_error() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4);
        let series: Vec<f64> = (0..500).map(|_| rng.lognormal(0.0, 0.5)).collect();
        let mut p = MovingAverage::new(8);
        let e = evaluate(&mut p, &series, 100);
        // Log-normal σ=0.5 noise: predictors can't beat ~30% MAPE.
        assert!(e.mape_pct > 20.0, "mape={}", e.mape_pct);
        assert!(e.mae > 0.0);
    }

    #[test]
    #[should_panic]
    fn warmup_must_be_less_than_len() {
        let mut p = MovingAverage::new(2);
        evaluate(&mut p, &[1.0, 2.0], 2);
    }
}
