//! TTFT predictors (Appendix C, Table 5).
//!
//! The paper evaluates four lightweight time-series predictors on server
//! TTFT traces and shows none reaches useful accuracy (MAPE ≥ 20%) — the
//! negative result motivating DiSCo's distribution-based planning instead
//! of point prediction. All four are implemented from scratch here
//! (moving average, exponential smoothing, random forest, gradient-boosted
//! trees) plus the walk-forward MAPE/MAE evaluation harness.

pub mod eval;
pub mod forest;
pub mod gbdt;
pub mod smoothing;
pub mod tree;

pub use eval::{evaluate, PredEval};

/// A one-step-ahead time-series predictor.
pub trait Predictor {
    fn name(&self) -> &'static str;
    /// Fit on an initial history (walk-forward evaluation refits never —
    /// matching lightweight on-device deployment).
    fn fit(&mut self, history: &[f64]);
    /// Predict the next value given everything observed so far.
    fn predict_next(&self, history: &[f64]) -> f64;
}

/// The paper's four predictors with their Table 5 configurations.
pub fn table5_predictors() -> Vec<Box<dyn Predictor>> {
    vec![
        Box::new(smoothing::MovingAverage::new(8)),
        Box::new(smoothing::ExponentialSmoothing::new(0.3)),
        Box::new(forest::RandomForest::new(20, 4, 8, 0x5EED)),
        Box::new(gbdt::Gbdt::new(40, 3, 0.1, 8)),
    ]
}
