//! Moving-average and exponential-smoothing predictors.

use crate::predictor::Predictor;

/// Mean of the last `window` observations.
#[derive(Clone, Debug)]
pub struct MovingAverage {
    pub window: usize,
}

impl MovingAverage {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingAverage { window }
    }
}

impl Predictor for MovingAverage {
    fn name(&self) -> &'static str {
        "Moving Average"
    }
    fn fit(&mut self, _history: &[f64]) {}
    fn predict_next(&self, history: &[f64]) -> f64 {
        if history.is_empty() {
            return 0.0;
        }
        let start = history.len().saturating_sub(self.window);
        crate::stats::describe::mean(&history[start..])
    }
}

/// Simple exponential smoothing: s_t = γ·x_t + (1−γ)·s_{t−1}.
#[derive(Clone, Debug)]
pub struct ExponentialSmoothing {
    pub gamma: f64,
}

impl ExponentialSmoothing {
    pub fn new(gamma: f64) -> Self {
        assert!((0.0..=1.0).contains(&gamma));
        ExponentialSmoothing { gamma }
    }
}

impl Predictor for ExponentialSmoothing {
    fn name(&self) -> &'static str {
        "ExponentialSmoothing"
    }
    fn fit(&mut self, _history: &[f64]) {}
    fn predict_next(&self, history: &[f64]) -> f64 {
        let mut s = match history.first() {
            Some(&x) => x,
            None => return 0.0,
        };
        for &x in &history[1..] {
            s = self.gamma * x + (1.0 - self.gamma) * s;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ma_of_constant_is_constant() {
        let ma = MovingAverage::new(4);
        assert_eq!(ma.predict_next(&[2.0; 10]), 2.0);
        assert_eq!(ma.predict_next(&[]), 0.0);
    }

    #[test]
    fn ma_uses_only_window() {
        let ma = MovingAverage::new(2);
        // Last two values are 10, 20.
        assert!((ma.predict_next(&[1000.0, 10.0, 20.0]) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn es_converges_to_level() {
        let es = ExponentialSmoothing::new(0.5);
        let hist = vec![4.0; 50];
        assert!((es.predict_next(&hist) - 4.0).abs() < 1e-9);
        // Step change tracks toward the new level.
        let mut hist = vec![0.0; 10];
        hist.extend(vec![10.0; 10]);
        let p = es.predict_next(&hist);
        assert!(p > 9.0 && p <= 10.0, "p={p}");
    }
}
