//! CART-style regression tree on lag features — the building block for
//! the random forest and GBDT predictors.

/// A binary regression tree (greedy variance-reduction splits).
#[derive(Clone, Debug)]
pub struct RegressionTree {
    pub max_depth: usize,
    pub min_samples: usize,
    nodes: Vec<Node>,
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

impl RegressionTree {
    pub fn new(max_depth: usize, min_samples: usize) -> Self {
        RegressionTree {
            max_depth,
            min_samples: min_samples.max(2),
            nodes: Vec::new(),
        }
    }

    /// Fit on rows `x` (each a feature vector) with targets `y`.
    pub fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        self.nodes.clear();
        if x.is_empty() {
            self.nodes.push(Node::Leaf { value: 0.0 });
            return;
        }
        let idx: Vec<usize> = (0..x.len()).collect();
        self.build(x, y, idx, 0);
    }

    fn build(&mut self, x: &[Vec<f64>], y: &[f64], idx: Vec<usize>, depth: usize) -> usize {
        let mean = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
        if depth >= self.max_depth || idx.len() < self.min_samples {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        }
        // Greedy best split by SSE reduction.
        let n_features = x[idx[0]].len();
        let parent_sse: f64 = idx.iter().map(|&i| (y[i] - mean) * (y[i] - mean)).sum();
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, sse)
        for f in 0..n_features {
            let mut vals: Vec<(f64, f64)> = idx.iter().map(|&i| (x[i][f], y[i])).collect();
            vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // Prefix sums for O(n) split scan.
            let total_sum: f64 = vals.iter().map(|v| v.1).sum();
            let total_sq: f64 = vals.iter().map(|v| v.1 * v.1).sum();
            let mut lsum = 0.0;
            let mut lsq = 0.0;
            for k in 0..vals.len() - 1 {
                lsum += vals[k].1;
                lsq += vals[k].1 * vals[k].1;
                if vals[k].0 == vals[k + 1].0 {
                    continue; // can't split between equal values
                }
                let nl = (k + 1) as f64;
                let nr = (vals.len() - k - 1) as f64;
                let sse_l = lsq - lsum * lsum / nl;
                let rsum = total_sum - lsum;
                let sse_r = (total_sq - lsq) - rsum * rsum / nr;
                let sse = sse_l + sse_r;
                if best.map(|(_, _, b)| sse < b).unwrap_or(sse < parent_sse - 1e-12) {
                    best = Some((f, (vals[k].0 + vals[k + 1].0) / 2.0, sse));
                }
            }
        }
        match best {
            None => {
                self.nodes.push(Node::Leaf { value: mean });
                self.nodes.len() - 1
            }
            Some((feature, threshold, _)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.into_iter().partition(|&i| x[i][feature] <= threshold);
                let placeholder = self.nodes.len();
                self.nodes.push(Node::Leaf { value: mean }); // replaced below
                let left = self.build(x, y, li, depth + 1);
                let right = self.build(x, y, ri, depth + 1);
                self.nodes[placeholder] = Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                };
                placeholder
            }
        }
    }

    /// Predict one row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// Build lag-feature rows from a series: row t = [x_{t-k}..x_{t-1}],
/// target x_t.
pub fn lag_features(series: &[f64], lags: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for t in lags..series.len() {
        xs.push(series[t - lags..t].to_vec());
        ys.push(series[t]);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_step_function() {
        // y = 1 if x > 0.5 else 0 — one split suffices.
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<f64> = x.iter().map(|r| if r[0] > 0.5 { 1.0 } else { 0.0 }).collect();
        let mut t = RegressionTree::new(3, 2);
        t.fit(&x, &y);
        assert!(t.predict(&[0.1]) < 0.1);
        assert!(t.predict(&[0.9]) > 0.9);
    }

    #[test]
    fn deeper_tree_fits_xor_grid() {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for a in 0..10 {
            for b in 0..10 {
                let (fa, fb) = (a as f64 / 10.0, b as f64 / 10.0);
                x.push(vec![fa, fb]);
                y.push(if (fa > 0.5) ^ (fb > 0.5) { 1.0 } else { 0.0 });
            }
        }
        let mut t = RegressionTree::new(4, 2);
        t.fit(&x, &y);
        assert!(t.predict(&[0.9, 0.1]) > 0.8);
        assert!(t.predict(&[0.9, 0.9]) < 0.2);
    }

    #[test]
    fn constant_target_is_leaf() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y = vec![3.0; 10];
        let mut t = RegressionTree::new(5, 2);
        t.fit(&x, &y);
        assert_eq!(t.predict(&[4.2]), 3.0);
    }

    #[test]
    fn empty_fit_predicts_zero() {
        let mut t = RegressionTree::new(3, 2);
        t.fit(&[], &[]);
        assert_eq!(t.predict(&[1.0]), 0.0);
    }

    #[test]
    fn lag_features_shapes() {
        let series = [1.0, 2.0, 3.0, 4.0, 5.0];
        let (x, y) = lag_features(&series, 2);
        assert_eq!(x.len(), 3);
        assert_eq!(x[0], vec![1.0, 2.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
    }
}
