//! Gradient-boosted regression trees (the paper's "XGBoost" row):
//! stagewise least-squares boosting with shrinkage.

use crate::predictor::tree::{lag_features, RegressionTree};
use crate::predictor::Predictor;

/// L2-boosting over shallow regression trees.
pub struct Gbdt {
    pub n_rounds: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    pub lags: usize,
    base: f64,
    trees: Vec<RegressionTree>,
}

impl Gbdt {
    pub fn new(n_rounds: usize, max_depth: usize, learning_rate: f64, lags: usize) -> Self {
        Gbdt {
            n_rounds,
            max_depth,
            learning_rate,
            lags,
            base: 0.0,
            trees: Vec::new(),
        }
    }

    fn predict_row(&self, row: &[f64]) -> f64 {
        self.base
            + self
                .trees
                .iter()
                .map(|t| self.learning_rate * t.predict(row))
                .sum::<f64>()
    }
}

impl Predictor for Gbdt {
    fn name(&self) -> &'static str {
        "XGBoost"
    }

    fn fit(&mut self, history: &[f64]) {
        self.trees.clear();
        self.base = crate::stats::describe::mean(history);
        let (x, y) = lag_features(history, self.lags);
        if x.len() < 4 {
            return;
        }
        let mut residuals: Vec<f64> = y.iter().map(|v| v - self.base).collect();
        for _ in 0..self.n_rounds {
            let mut t = RegressionTree::new(self.max_depth, 4);
            t.fit(&x, &residuals);
            for (i, row) in x.iter().enumerate() {
                residuals[i] -= self.learning_rate * t.predict(row);
            }
            self.trees.push(t);
        }
    }

    fn predict_next(&self, history: &[f64]) -> f64 {
        if self.trees.is_empty() || history.len() < self.lags {
            return if history.is_empty() {
                0.0
            } else {
                crate::stats::describe::mean(history)
            };
        }
        self.predict_row(&history[history.len() - self.lags..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boosting_reduces_training_error() {
        // Sinusoid: boosting should fit much better than the mean.
        let series: Vec<f64> = (0..300).map(|i| (i as f64 * 0.3).sin() + 2.0).collect();
        let mut g = Gbdt::new(50, 3, 0.2, 6);
        g.fit(&series);
        // Walk-forward error on the tail must beat the mean predictor.
        let mut err_g = 0.0;
        let mut err_mean = 0.0;
        for t in 250..300 {
            let hist = &series[..t];
            err_g += (g.predict_next(hist) - series[t]).abs();
            err_mean += (crate::stats::describe::mean(hist) - series[t]).abs();
        }
        assert!(
            err_g < 0.5 * err_mean,
            "gbdt {err_g:.3} vs mean {err_mean:.3}"
        );
    }

    #[test]
    fn short_history_fallback() {
        let mut g = Gbdt::new(10, 3, 0.1, 8);
        g.fit(&[1.0, 2.0]);
        assert!((g.predict_next(&[3.0, 5.0]) - 4.0).abs() < 1e-9);
        assert_eq!(g.predict_next(&[]), 0.0);
    }
}
