//! Random forest: bagged regression trees over lag features.

use crate::predictor::tree::{lag_features, RegressionTree};
use crate::predictor::Predictor;
use crate::util::rng::Rng;

/// Bootstrap-aggregated regression trees (the paper's "Random Forest").
pub struct RandomForest {
    pub n_trees: usize,
    pub max_depth: usize,
    pub lags: usize,
    seed: u64,
    trees: Vec<RegressionTree>,
    fallback: f64,
}

impl RandomForest {
    pub fn new(n_trees: usize, max_depth: usize, lags: usize, seed: u64) -> Self {
        RandomForest {
            n_trees,
            max_depth,
            lags,
            seed,
            trees: Vec::new(),
            fallback: 0.0,
        }
    }
}

impl Predictor for RandomForest {
    fn name(&self) -> &'static str {
        "Random Forest"
    }

    fn fit(&mut self, history: &[f64]) {
        self.trees.clear();
        self.fallback = crate::stats::describe::mean(history);
        let (x, y) = lag_features(history, self.lags);
        if x.len() < 4 {
            return;
        }
        let mut rng = Rng::new(self.seed);
        for _ in 0..self.n_trees {
            // Bootstrap sample.
            let bx_by: Vec<(Vec<f64>, f64)> = (0..x.len())
                .map(|_| {
                    let i = rng.below(x.len() as u64) as usize;
                    (x[i].clone(), y[i])
                })
                .collect();
            let bx: Vec<Vec<f64>> = bx_by.iter().map(|(a, _)| a.clone()).collect();
            let by: Vec<f64> = bx_by.iter().map(|(_, b)| *b).collect();
            let mut t = RegressionTree::new(self.max_depth, 4);
            t.fit(&bx, &by);
            self.trees.push(t);
        }
    }

    fn predict_next(&self, history: &[f64]) -> f64 {
        if self.trees.is_empty() || history.len() < self.lags {
            return if history.is_empty() {
                self.fallback
            } else {
                crate::stats::describe::mean(history)
            };
        }
        let row = &history[history.len() - self.lags..];
        let sum: f64 = self.trees.iter().map(|t| t.predict(row)).sum();
        sum / self.trees.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_autoregressive_pattern() {
        // x_t = 0.9·x_{t-1}: the forest should predict a value close to
        // 0.9 times the last observation.
        let mut series = vec![10.0];
        for _ in 0..400 {
            series.push(series.last().unwrap() * 0.9 + 0.5);
        }
        let mut rf = RandomForest::new(10, 4, 4, 1);
        rf.fit(&series);
        let pred = rf.predict_next(&series);
        let expected = series.last().unwrap() * 0.9 + 0.5;
        assert!(
            (pred - expected).abs() < 0.5,
            "pred={pred} expected={expected}"
        );
    }

    #[test]
    fn short_history_falls_back_to_mean() {
        let mut rf = RandomForest::new(5, 3, 8, 2);
        rf.fit(&[1.0, 2.0, 3.0]);
        let p = rf.predict_next(&[4.0, 6.0]);
        assert!((p - 5.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let series: Vec<f64> = (0..200).map(|i| ((i * 7) % 13) as f64).collect();
        let mut a = RandomForest::new(8, 4, 4, 7);
        let mut b = RandomForest::new(8, 4, 4, 7);
        a.fit(&series);
        b.fit(&series);
        assert_eq!(a.predict_next(&series), b.predict_next(&series));
    }
}
