//! Live serving loop: the coordinator over a REAL device endpoint.
//!
//! The device endpoint executes the AOT-compiled transformer through PJRT
//! (`runtime::ModelRunner`); the server endpoint is emulated in wall-clock
//! time from a calibrated service profile (no network offline). Both race
//! per the dispatch decision exactly as in simulation — first token wins,
//! the loser is cooperatively cancelled — proving the three layers
//! compose on a real request path.
//!
//! Threading note: the `xla` crate's handles are not `Send` (internal
//! `Rc`s), so the real model runs on the coordinator thread while the
//! emulated server runs on a spawned thread; the race is resolved by
//! first-token timestamps, and the device cancels cooperatively through
//! its streaming callback. tokio is unavailable offline; this is plain
//! threads + channels.

use crate::coordinator::dispatch::Decision;
use crate::coordinator::policy::Policy;
use crate::endpoint::EndpointKind;
use crate::profiles::server::ServerProfile;
use crate::runtime::model_runner::ModelRunner;
use crate::sim::delivery;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One live request.
#[derive(Clone, Debug)]
pub struct LiveRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: u32,
}

/// Measured outcome of one live request.
#[derive(Clone, Debug)]
pub struct LiveRecord {
    pub id: u64,
    pub prompt_len: u32,
    pub winner: EndpointKind,
    /// Wall-clock TTFT (seconds).
    pub ttft: f64,
    /// Raw generation gaps from the winning endpoint.
    pub gaps: Vec<f64>,
    /// Perceived TBTs after delivery smoothing.
    pub tbts: Vec<f64>,
    pub delay_num: u32,
    pub tokens: Vec<u32>,
    /// Decoded text (device tokens are real model output).
    pub text: String,
}

/// Live loop configuration.
#[derive(Clone, Copy, Debug)]
pub struct LiveConfig {
    /// Wall-clock scale on the *emulated server* latencies (<1 speeds up
    /// demos without touching real device compute).
    pub server_time_scale: f64,
    /// Consumption rate for delivery smoothing (unscaled).
    pub consumption_rate: f64,
    pub seed: u64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            server_time_scale: 1.0,
            consumption_rate: 5.0,
            seed: 0,
        }
    }
}

/// Timestamped token from the emulated server.
#[derive(Clone, Copy, Debug)]
struct ServerToken {
    token: u32,
    at: f64,
}

/// The live coordinator.
pub struct LiveServer {
    pub runner: ModelRunner,
    pub server_profile: ServerProfile,
    pub config: LiveConfig,
}

impl LiveServer {
    pub fn new(runner: ModelRunner, server_profile: ServerProfile, config: LiveConfig) -> Self {
        LiveServer {
            runner,
            server_profile,
            config,
        }
    }

    /// Serve a batch of requests sequentially (the device is single-flight
    /// hardware; concurrency happens *within* a request via the race).
    pub fn serve(&self, requests: &[LiveRequest], policy: &Policy) -> Vec<LiveRecord> {
        let mut rng = Rng::new(self.config.seed);
        requests
            .iter()
            .map(|r| self.serve_one(r, policy, &mut rng))
            .collect()
    }

    fn spawn_server(
        &self,
        max_new: u32,
        rng: &mut Rng,
        t0: Instant,
        cancel: Arc<AtomicBool>,
    ) -> Receiver<ServerToken> {
        let (tx, rx) = mpsc::channel::<ServerToken>();
        let profile = self.server_profile.clone();
        let scale = self.config.server_time_scale;
        let mut srng = rng.fork(0x5e);
        std::thread::spawn(move || {
            let ttft = profile.sample_ttft(&mut srng) * scale;
            sleep_unless(ttft, &cancel);
            if cancel.load(Ordering::Relaxed) {
                return;
            }
            // Emulated content: printable bytes (not model output).
            let _ = tx.send(ServerToken {
                token: 32 + (srng.below(95) as u32),
                at: t0.elapsed().as_secs_f64(),
            });
            let mut emitted = 1u32;
            for gap in profile.sample_gaps(max_new.saturating_sub(1), &mut srng) {
                sleep_unless(gap * scale, &cancel);
                if cancel.load(Ordering::Relaxed) {
                    return;
                }
                if tx
                    .send(ServerToken {
                        token: 32 + (srng.below(95) as u32),
                        at: t0.elapsed().as_secs_f64(),
                    })
                    .is_err()
                {
                    return;
                }
                emitted += 1;
                if emitted >= max_new {
                    return;
                }
            }
        });
        rx
    }

    fn serve_one(&self, req: &LiveRequest, policy: &Policy, rng: &mut Rng) -> LiveRecord {
        let decision = policy.decide(req.prompt.len() as u32, rng);
        let t0 = Instant::now();
        let cancel_server = Arc::new(AtomicBool::new(false));
        let scale = self.config.server_time_scale;

        let server_rx = if decision.uses_server() {
            Some(self.spawn_server(req.max_new, rng, t0, cancel_server.clone()))
        } else {
            None
        };

        let device_wait = match decision {
            Decision::DeviceOnly => 0.0,
            Decision::ServerOnly => f64::INFINITY,
            Decision::Both { device_wait } => device_wait,
        };
        let use_device = decision.uses_device() && device_wait.is_finite();

        let mut server_tokens: Vec<ServerToken> = Vec::new();
        let drain = |rx: &Receiver<ServerToken>, out: &mut Vec<ServerToken>| loop {
            match rx.try_recv() {
                Ok(t) => out.push(t),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        };

        // Wait-time strategy: idle until device_wait, watching the server.
        let mut server_won_early = false;
        if use_device {
            let deadline = t0 + Duration::from_secs_f64(device_wait * scale);
            while Instant::now() < deadline {
                if let Some(rx) = &server_rx {
                    drain(rx, &mut server_tokens);
                    if !server_tokens.is_empty() {
                        server_won_early = true;
                        break;
                    }
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }

        // Run the real device model unless the server already answered.
        let mut device_events: Vec<(u32, f64)> = Vec::new();
        if use_device && !server_won_early {
            let res = self.runner.generate_with(&req.prompt, req.max_new, |e| {
                if let Some(rx) = &server_rx {
                    drain(rx, &mut server_tokens);
                }
                let at = t0.elapsed().as_secs_f64();
                // If the server produced its first token before the device
                // did, the server won the race: stop device generation.
                let lost = device_events.is_empty()
                    && server_tokens.first().map(|s| s.at < at).unwrap_or(false);
                if !lost {
                    device_events.push((e.token, at));
                }
                !lost
            });
            if let Err(e) = res {
                log::error!("device generation failed: {e:#}");
            }
        }

        // Decide the winner by first-token timestamps.
        let device_first = device_events.first().map(|&(_, at)| at);
        let server_first = server_tokens.first().map(|s| s.at);
        let winner = match (device_first, server_first) {
            (Some(d), Some(s)) => {
                if d <= s {
                    EndpointKind::Device
                } else {
                    EndpointKind::Server
                }
            }
            (Some(_), None) => EndpointKind::Device,
            _ => EndpointKind::Server,
        };

        let (tokens, times): (Vec<u32>, Vec<f64>) = match winner {
            EndpointKind::Device => {
                cancel_server.store(true, Ordering::Relaxed);
                device_events.iter().copied().unzip()
            }
            EndpointKind::Server => {
                // Collect the remaining server stream (blocking).
                if let Some(rx) = &server_rx {
                    while server_tokens.len() < req.max_new as usize {
                        match rx.recv_timeout(Duration::from_secs(30)) {
                            Ok(t) => server_tokens.push(t),
                            Err(_) => break,
                        }
                    }
                }
                server_tokens.iter().map(|s| (s.token, s.at)).unzip()
            }
        };

        let ttft = times.first().copied().unwrap_or(0.0);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        // Smooth at a scaled consumption rate so perceived pacing matches
        // the scaled clock.
        let r_c = self.config.consumption_rate / scale.max(1e-9);
        let d = delivery::smooth(&times, r_c);
        let text = self.runner.tokenizer.decode(&tokens);
        LiveRecord {
            id: req.id,
            prompt_len: req.prompt.len() as u32,
            winner,
            ttft,
            gaps,
            tbts: d.tbts,
            delay_num: d.delay_num,
            tokens,
            text,
        }
    }
}

/// Sleep in small slices so cancellation stays responsive.
fn sleep_unless(secs: f64, cancel: &AtomicBool) {
    let deadline = Instant::now() + Duration::from_secs_f64(secs.max(0.0));
    while Instant::now() < deadline {
        if cancel.load(Ordering::Relaxed) {
            return;
        }
        let left = deadline - Instant::now();
        std::thread::sleep(left.min(Duration::from_millis(2)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyKind;
    use crate::runtime::manifest::Manifest;

    fn live_server() -> Option<LiveServer> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping live test: artifacts not built");
            return None;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let client = xla::PjRtClient::cpu().unwrap();
        let runner = ModelRunner::load(&client, manifest.variant("device_sm").unwrap()).unwrap();
        Some(LiveServer::new(
            runner,
            ServerProfile::gpt4o_mini(),
            LiveConfig {
                server_time_scale: 0.05,
                consumption_rate: 5.0,
                seed: 3,
            },
        ))
    }

    #[test]
    fn live_race_produces_tokens() {
        let Some(srv) = live_server() else { return };
        let reqs: Vec<LiveRequest> = (0..3)
            .map(|i| LiveRequest {
                id: i,
                prompt: srv.runner.tokenizer.encode("hello disco"),
                max_new: 6,
            })
            .collect();
        let policy = Policy::simple(PolicyKind::StochD, 1.0, false); // always race
        let records = srv.serve(&reqs, &policy);
        assert_eq!(records.len(), 3);
        for r in &records {
            assert!(!r.tokens.is_empty());
            assert!(r.ttft > 0.0);
            assert_eq!(r.gaps.len() + 1, r.tokens.len());
        }
    }

    #[test]
    fn device_only_runs_real_model() {
        let Some(srv) = live_server() else { return };
        let reqs = vec![LiveRequest {
            id: 0,
            prompt: srv.runner.tokenizer.encode("abc"),
            max_new: 5,
        }];
        let policy = Policy::simple(PolicyKind::DeviceOnly, 1.0, false);
        let records = srv.serve(&reqs, &policy);
        assert_eq!(records[0].winner, EndpointKind::Device);
        assert!(records[0].tokens.len() <= 5);
        assert!(!records[0].text.is_empty() || records[0].tokens == vec![257]);
    }

    #[test]
    fn server_only_never_touches_device() {
        let Some(srv) = live_server() else { return };
        let reqs = vec![LiveRequest {
            id: 0,
            prompt: srv.runner.tokenizer.encode("xyz"),
            max_new: 4,
        }];
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let records = srv.serve(&reqs, &policy);
        assert_eq!(records[0].winner, EndpointKind::Server);
        assert_eq!(records[0].tokens.len(), 4);
    }

    #[test]
    fn sleep_unless_cancels_quickly() {
        let flag = AtomicBool::new(false);
        let t0 = Instant::now();
        sleep_unless(0.02, &flag);
        assert!(t0.elapsed().as_secs_f64() >= 0.015);
        let flag = AtomicBool::new(true);
        let t0 = Instant::now();
        sleep_unless(5.0, &flag);
        assert!(t0.elapsed().as_secs_f64() < 0.5);
    }
}
