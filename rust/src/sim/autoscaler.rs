//! Shard autoscaling: let the fleet's replica count K react to load.
//!
//! The paper's cost model leans on the "flexible capacity" of
//! server-based inference but never prices what flexing costs: spinning
//! up a replica pays a model-load delay that
//! [`crate::endpoint::coldstart::ColdStartProfile`] already quantifies
//! (Appendix B, Table 4). This module supplies the *policy* side of that
//! trade-off; the *mechanics* (cold shards, draining, retirement) live in
//! the [`crate::sim::fleet`] event loop.
//!
//! An [`Autoscaler`] is evaluated periodically (every
//! [`AutoscaleConfig::eval_interval`] simulated seconds) against a
//! [`FleetView`] snapshot and returns a [`ScaleAction`]:
//!
//! * **Scale-out** creates a shard that is *cold*: it admits no work
//!   until a load-time delay from the configured [`ColdStartSpec`]
//!   elapses, then warms and joins the balanced set.
//! * **Scale-in** drains a victim shard: no new admissions, existing
//!   streams finish, then the shard retires and stops accruing
//!   shard-seconds.
//!
//! Three policies ship:
//!
//! * [`AutoscalerKind::None`] — never scales; byte-identical to the
//!   static PR-2 fleet (no evaluation events are even scheduled).
//! * [`AutoscalerKind::Reactive`] — queue-depth thresholds with
//!   hysteresis (sustain counts + cooldown), the classic
//!   utilization-band autoscaler.
//! * [`AutoscalerKind::TtftTarget`] — scales out when the *predicted*
//!   admission queue delay (outstanding service seconds over provisioned
//!   capacity) would breach a TTFT deadline's queue-delay budget.
//!
//! Policies are deterministic: any randomness draws from a dedicated
//! fleet-level stream, disjoint from balancer and per-request streams.

use crate::endpoint::coldstart::ColdStartProfile;
use crate::sim::balancer::ShardView;
use crate::util::rng::Rng;

/// Lifecycle of a server shard under autoscaling.
///
/// Static fleets stay `Warm` forever; the autoscaled lifecycle is
/// `Cold → Warm → Draining → Retired` (cold-start, service, scale-in,
/// gone).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifecyclePhase {
    /// Loading the model; admits no work until the load delay elapses.
    Cold,
    /// In service: the balancer routes new requests here.
    Warm,
    /// Scale-in victim: no new admissions, existing streams finish.
    Draining,
    /// Fully drained; no longer accrues shard-seconds.
    Retired,
}

/// Autoscaler-visible snapshot of one shard at evaluation time.
#[derive(Clone, Copy, Debug)]
pub struct ShardStatus {
    /// The balancer-level occupancy snapshot.
    pub view: ShardView,
    /// Where the shard is in its lifecycle.
    pub phase: LifecyclePhase,
}

/// Fleet snapshot handed to [`Autoscaler::evaluate`].
#[derive(Debug)]
pub struct FleetView<'a> {
    /// Simulated time of this evaluation (seconds).
    pub now: f64,
    /// One status per shard ever provisioned (including retired ones, so
    /// indices are stable).
    pub shards: &'a [ShardStatus],
    /// Concurrent admissions per shard (`None` = unlimited).
    pub slots_per_shard: Option<usize>,
    /// The fleet's configured band. The fleet clamps every action to it
    /// anyway; policies use it to avoid *emitting* actions that would be
    /// clamped to no-ops (which would still consume their cooldown).
    pub min_shards: usize,
    /// Upper bound of the band (see `min_shards`).
    pub max_shards: usize,
    /// Prompt-token admission rate per shard under continuous batching
    /// (`prefill_tokens_per_tick / tick_interval`); `None` for slot
    /// fleets. When set, policies re-derive their load signals from the
    /// token backlog instead of slot occupancy: the queue-depth and
    /// predicted-delay signals become *seconds of queued prefill work*
    /// per shard.
    pub prefill_tokens_per_sec: Option<f64>,
}

impl FleetView<'_> {
    /// Shards currently admitting new work.
    pub fn warm_count(&self) -> usize {
        self.count(LifecyclePhase::Warm)
    }

    /// Shards still loading their model.
    pub fn cold_count(&self) -> usize {
        self.count(LifecyclePhase::Cold)
    }

    /// Capacity already paid for: warm shards plus in-flight warm-ups.
    /// Scaling decisions should use this, not `warm_count`, so a policy
    /// does not re-fire while a previous scale-out is still loading.
    pub fn provisioned_count(&self) -> usize {
        self.warm_count() + self.cold_count()
    }

    fn count(&self, phase: LifecyclePhase) -> usize {
        self.shards.iter().filter(|s| s.phase == phase).count()
    }

    /// Total outstanding requests (running + queued) on live shards.
    pub fn outstanding(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .map(|s| s.view.outstanding())
            .sum()
    }

    /// Total outstanding *estimated service seconds* on live shards (the
    /// pre-drawn prefill samples of queued + in-service requests).
    pub fn outstanding_work(&self) -> f64 {
        self.shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .map(|s| s.view.work)
            .sum()
    }

    /// Streams currently in service on live shards (holding a slot, or
    /// decoding in the shard batches under continuous batching).
    pub fn in_service(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .map(|s| s.view.in_use)
            .sum()
    }

    /// Total prompt tokens queued for admission on live shards — the
    /// backlog the token gates still have to clear under continuous
    /// batching.
    pub fn queued_prompt_tokens(&self) -> u64 {
        self.shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .map(|s| s.view.queued_tokens)
            .sum()
    }

    /// Seconds of queued prefill work across the fleet under continuous
    /// batching (`None` for slot fleets): the token backlog over one
    /// shard's admission rate — the time a single shard would need to
    /// clear it.
    pub fn queued_backlog_seconds(&self) -> Option<f64> {
        match self.prefill_tokens_per_sec {
            Some(rate) if rate > 0.0 => Some(self.queued_prompt_tokens() as f64 / rate),
            _ => None,
        }
    }
}

/// What the autoscaler wants done. The fleet clamps every action to the
/// configured `[min_shards, max_shards]` band before applying it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// Keep the current topology.
    Hold,
    /// Provision this many new (cold) shards.
    ScaleOut {
        /// Number of shards to add.
        shards: usize,
    },
    /// Drain this many warm shards.
    ScaleIn {
        /// Number of shards to drain.
        shards: usize,
    },
}

/// A shard-count policy, evaluated periodically by the fleet loop.
pub trait Autoscaler {
    /// Short label used in tables and event logs.
    fn name(&self) -> &'static str;

    /// Inspect the fleet and decide. `rng` is a dedicated fleet-level
    /// stream (disjoint from balancer and per-request streams), so
    /// randomized policies stay deterministic without perturbing request
    /// trajectories.
    fn evaluate(&mut self, fleet: &FleetView<'_>, rng: &mut Rng) -> ScaleAction;
}

// ---------------------------------------------------------------------
// Cold-start model
// ---------------------------------------------------------------------

/// Where a new shard's load-time delay comes from.
#[derive(Clone, Copy, Debug)]
pub enum ColdStartSpec {
    /// Fixed delay in seconds (tests, what-if sweeps).
    Fixed(f64),
    /// Appendix-B load model: `ColdStartProfile::load_time(params_b)`.
    Model {
        /// Host platform characteristics (Table 4 fit).
        profile: ColdStartProfile,
        /// Model size in billions of parameters.
        params_b: f64,
    },
}

impl ColdStartSpec {
    /// The Appendix-B default: an A40 host loading a 7B model (~14.2 s
    /// under the fitted load model; Table 4 measures 13.43 s).
    pub fn a40_7b() -> ColdStartSpec {
        ColdStartSpec::Model {
            profile: ColdStartProfile::a40(),
            params_b: 7.0,
        }
    }

    /// An RTX 3060 host loading a 3B model (~4.4 s).
    pub fn rtx3060_3b() -> ColdStartSpec {
        ColdStartSpec::Model {
            profile: ColdStartProfile::rtx3060(),
            params_b: 3.0,
        }
    }

    /// Seconds a freshly provisioned shard spends cold.
    pub fn delay(&self) -> f64 {
        match self {
            ColdStartSpec::Fixed(s) => s.max(0.0),
            ColdStartSpec::Model { profile, params_b } => profile.load_time(*params_b),
        }
    }

    /// Short label for tables and CSVs.
    pub fn label(&self) -> String {
        match self {
            ColdStartSpec::Fixed(s) => format!("fixed:{s}"),
            ColdStartSpec::Model { profile, params_b } => {
                let p = if profile.platform.starts_with("RTX") {
                    "rtx3060"
                } else {
                    "a40"
                };
                format!("{p}:{params_b}B")
            }
        }
    }

    /// Parse a CLI spelling: `fixed:SECS`, `rtx3060:PARAMS_B`, or
    /// `a40:PARAMS_B` (bare `rtx3060` / `a40` default to 3B / 7B).
    pub fn parse(s: &str) -> Option<ColdStartSpec> {
        let lower = s.to_ascii_lowercase();
        let (head, tail) = match lower.split_once(':') {
            Some((h, t)) => (h, Some(t)),
            None => (lower.as_str(), None),
        };
        let num = |t: Option<&str>, default: f64| -> Option<f64> {
            match t {
                None => Some(default),
                Some(t) => t.trim_end_matches(['b', 'B']).parse::<f64>().ok(),
            }
        };
        match head {
            "fixed" => Some(ColdStartSpec::Fixed(num(tail, 0.0)?)),
            "rtx3060" => Some(ColdStartSpec::Model {
                profile: ColdStartProfile::rtx3060(),
                params_b: num(tail, 3.0)?,
            }),
            "a40" => Some(ColdStartSpec::Model {
                profile: ColdStartProfile::a40(),
                params_b: num(tail, 7.0)?,
            }),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// Policies
// ---------------------------------------------------------------------

/// Queue-depth autoscaler with hysteresis: scale out when outstanding
/// requests per provisioned shard stay above a high watermark, scale in
/// when they stay below a low watermark.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReactiveConfig {
    /// High watermark: outstanding requests per provisioned shard that
    /// triggers scale-out.
    pub scale_out_per_shard: f64,
    /// Low watermark: outstanding requests per provisioned shard below
    /// which the fleet scales in.
    pub scale_in_per_shard: f64,
    /// Consecutive evaluations a watermark must hold before acting
    /// (hysteresis against transient blips).
    pub sustain: u32,
    /// Minimum seconds between scale actions.
    pub cooldown: f64,
    /// Most shards added by a single scale-out action.
    pub max_step: usize,
}

impl Default for ReactiveConfig {
    fn default() -> Self {
        ReactiveConfig {
            scale_out_per_shard: 3.0,
            scale_in_per_shard: 0.5,
            sustain: 2,
            cooldown: 10.0,
            max_step: 2,
        }
    }
}

/// Runtime state of the reactive policy.
#[derive(Debug)]
pub struct Reactive {
    cfg: ReactiveConfig,
    hi_streak: u32,
    lo_streak: u32,
    last_action: f64,
}

impl Reactive {
    /// Build with the given thresholds.
    pub fn new(cfg: ReactiveConfig) -> Reactive {
        Reactive {
            cfg,
            hi_streak: 0,
            lo_streak: 0,
            last_action: f64::NEG_INFINITY,
        }
    }
}

impl Autoscaler for Reactive {
    fn name(&self) -> &'static str {
        "reactive"
    }

    fn evaluate(&mut self, fleet: &FleetView<'_>, _rng: &mut Rng) -> ScaleAction {
        let provisioned = fleet.provisioned_count().max(1);
        // Load signal: outstanding requests per provisioned shard on
        // slot fleets. Under continuous batching the signal is the
        // *worse* of (a) the prefill backlog — seconds of queued tokens
        // per shard, the admission pressure — and (b) the decode batch
        // depth — in-service streams per shard. The token gate admits
        // prefills freely, so without (b) a saturated batch (deep
        // batches, degrading TBT, empty admission queue) would be
        // invisible and the fleet could never scale out on decode load.
        let demand = match fleet.queued_backlog_seconds() {
            Some(backlog) => backlog.max(fleet.in_service() as f64),
            None => fleet.outstanding() as f64,
        };
        let per = demand / provisioned as f64;
        if per > self.cfg.scale_out_per_shard {
            self.hi_streak += 1;
            self.lo_streak = 0;
        } else if per < self.cfg.scale_in_per_shard {
            self.lo_streak += 1;
            self.hi_streak = 0;
        } else {
            self.hi_streak = 0;
            self.lo_streak = 0;
        }
        if fleet.now - self.last_action < self.cfg.cooldown {
            return ScaleAction::Hold;
        }
        // Actions the fleet would clamp to a no-op (already at the band
        // edge) are not emitted — they must not consume the cooldown a
        // genuine action will need.
        if self.hi_streak >= self.cfg.sustain && provisioned < fleet.max_shards {
            // Enough shards to bring the per-shard load back under the
            // high watermark, capped by the step size.
            let desired = (demand / self.cfg.scale_out_per_shard).ceil() as usize;
            let n = desired
                .saturating_sub(provisioned)
                .clamp(1, self.cfg.max_step.max(1));
            self.hi_streak = 0;
            self.last_action = fleet.now;
            return ScaleAction::ScaleOut { shards: n };
        }
        if self.lo_streak >= self.cfg.sustain && fleet.warm_count() > fleet.min_shards {
            self.lo_streak = 0;
            self.last_action = fleet.now;
            return ScaleAction::ScaleIn { shards: 1 };
        }
        ScaleAction::Hold
    }
}

/// Deadline-driven autoscaler: keeps the *predicted* admission queue
/// delay — outstanding service seconds spread over provisioned capacity —
/// under a TTFT deadline's queue-delay budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TtftTargetConfig {
    /// Queue-delay budget (seconds) carved out of the TTFT deadline; the
    /// remainder of the deadline covers the prefill itself.
    pub target_delay_s: f64,
    /// Scale in only when the fleet *minus one warm shard* would still
    /// keep predicted delay under `target_delay_s × scale_in_margin`.
    pub scale_in_margin: f64,
    /// Minimum seconds between scale actions.
    pub cooldown: f64,
    /// Most shards added by a single scale-out action.
    pub max_step: usize,
}

impl Default for TtftTargetConfig {
    fn default() -> Self {
        TtftTargetConfig {
            target_delay_s: 2.0,
            scale_in_margin: 0.5,
            cooldown: 5.0,
            max_step: 4,
        }
    }
}

/// Runtime state of the TTFT-target policy.
#[derive(Debug)]
pub struct TtftTarget {
    cfg: TtftTargetConfig,
    last_action: f64,
}

impl TtftTarget {
    /// Build with the given deadline budget.
    pub fn new(cfg: TtftTargetConfig) -> TtftTarget {
        TtftTarget {
            cfg,
            last_action: f64::NEG_INFINITY,
        }
    }
}

impl Autoscaler for TtftTarget {
    fn name(&self) -> &'static str {
        "ttft-target"
    }

    fn evaluate(&mut self, fleet: &FleetView<'_>, _rng: &mut Rng) -> ScaleAction {
        if fleet.now - self.last_action < self.cfg.cooldown {
            return ScaleAction::Hold;
        }
        let provisioned = fleet.provisioned_count().max(1);
        let slots = fleet.slots_per_shard;
        // The predictor's units: on slot fleets, outstanding service
        // seconds over provisioned slot capacity; under continuous
        // batching, the *worse* of the queued prompt-token backlog over
        // the admission token rate (admission delay) and the
        // outstanding service seconds (decode saturation — in-batch
        // streams keep their service estimate until release, so a deep
        // batch stays visible even with an empty admission queue), each
        // over one capacity unit per shard.
        let (work, per_shard_capacity) = match fleet.queued_backlog_seconds() {
            Some(backlog) => (backlog.max(fleet.outstanding_work()), 1.0),
            None => (
                fleet.outstanding_work(),
                slots.unwrap_or(1).max(1) as f64,
            ),
        };
        let predicted = work / (provisioned as f64 * per_shard_capacity);
        // Band-edge guards mirror Reactive's: never emit an action the
        // fleet would clamp to a no-op, or the cooldown is wasted.
        if predicted > self.cfg.target_delay_s && provisioned < fleet.max_shards {
            // Enough capacity to bring the predicted delay back under the
            // deadline budget (provisioned counts in-flight warm-ups, so
            // the policy does not re-fire while a cold shard loads).
            let desired =
                (work / (self.cfg.target_delay_s * per_shard_capacity)).ceil() as usize;
            let n = desired
                .saturating_sub(provisioned)
                .clamp(1, self.cfg.max_step.max(1));
            self.last_action = fleet.now;
            return ScaleAction::ScaleOut { shards: n };
        }
        let warm = fleet.warm_count();
        if warm > fleet.min_shards.max(1) {
            let after = work / (warm.saturating_sub(1).max(1) as f64 * per_shard_capacity);
            if after < self.cfg.target_delay_s * self.cfg.scale_in_margin {
                self.last_action = fleet.now;
                return ScaleAction::ScaleIn { shards: 1 };
            }
        }
        ScaleAction::Hold
    }
}

// ---------------------------------------------------------------------
// Selection + fleet-level configuration
// ---------------------------------------------------------------------

/// Selector for an [`Autoscaler`] policy; experiment grids and CLI flags
/// carry this tag (plus its tunables) rather than boxed trait objects.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AutoscalerKind {
    /// Never scale: the static fleet, byte-identical to PR-2 replays.
    None,
    /// Queue-depth thresholds with hysteresis.
    Reactive(ReactiveConfig),
    /// Predicted-queue-delay deadline policy.
    TtftTarget(TtftTargetConfig),
}

impl AutoscalerKind {
    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            AutoscalerKind::None => "none",
            AutoscalerKind::Reactive(_) => "reactive",
            AutoscalerKind::TtftTarget(_) => "ttft-target",
        }
    }

    /// Parse a CLI spelling (`none`, `reactive`, `ttft`/`ttft-target`),
    /// with default tunables.
    pub fn parse(s: &str) -> Option<AutoscalerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" | "fixed" | "static" => AutoscalerKind::None,
            "reactive" | "queue" => AutoscalerKind::Reactive(ReactiveConfig::default()),
            "ttft" | "ttft-target" | "deadline" => {
                AutoscalerKind::TtftTarget(TtftTargetConfig::default())
            }
            _ => return None,
        })
    }

    /// Instantiate the policy (fresh state); `None` for the static kind,
    /// which schedules no evaluation events at all.
    pub fn build(&self) -> Option<Box<dyn Autoscaler>> {
        match self {
            AutoscalerKind::None => None,
            AutoscalerKind::Reactive(cfg) => Some(Box::new(Reactive::new(*cfg))),
            AutoscalerKind::TtftTarget(cfg) => Some(Box::new(TtftTarget::new(*cfg))),
        }
    }
}

impl std::fmt::Display for AutoscalerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Fleet-level autoscaling configuration, attached to
/// `FleetConfig::autoscale`.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// The scaling policy.
    pub kind: AutoscalerKind,
    /// Seconds between autoscaler evaluations.
    pub eval_interval: f64,
    /// Never drain below this many warm shards (≥ 1 after normalization;
    /// this also guarantees the balancer always has an admitting shard).
    pub min_shards: usize,
    /// Never provision (warm + cold) beyond this many shards. Caps
    /// scale-out only; a fleet that *starts* above it is allowed.
    pub max_shards: usize,
    /// Load-time delay model for freshly provisioned shards.
    pub cold_start: ColdStartSpec,
}

impl AutoscaleConfig {
    /// The static policy: explicit "autoscaler disabled" configuration,
    /// byte-identical to omitting autoscaling entirely.
    pub fn fixed() -> AutoscaleConfig {
        AutoscaleConfig {
            kind: AutoscalerKind::None,
            ..AutoscaleConfig::default()
        }
    }

    /// Reactive defaults within the given shard band.
    pub fn reactive(min_shards: usize, max_shards: usize) -> AutoscaleConfig {
        AutoscaleConfig {
            kind: AutoscalerKind::Reactive(ReactiveConfig::default()),
            min_shards,
            max_shards,
            ..AutoscaleConfig::default()
        }
    }

    /// TTFT-target defaults within the given shard band.
    pub fn ttft_target(min_shards: usize, max_shards: usize) -> AutoscaleConfig {
        AutoscaleConfig {
            kind: AutoscalerKind::TtftTarget(TtftTargetConfig::default()),
            min_shards,
            max_shards,
            ..AutoscaleConfig::default()
        }
    }

    /// Clamp degenerate values (non-positive interval, zero minimum,
    /// inverted band) so the event loop never divides by zero or drains
    /// its last warm shard.
    pub fn normalized(&self) -> AutoscaleConfig {
        let min_shards = self.min_shards.max(1);
        AutoscaleConfig {
            kind: self.kind,
            eval_interval: if self.eval_interval > 0.0 {
                self.eval_interval
            } else {
                1.0
            },
            min_shards,
            max_shards: self.max_shards.max(min_shards),
            cold_start: self.cold_start,
        }
    }
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            kind: AutoscalerKind::None,
            eval_interval: 1.0,
            min_shards: 1,
            max_shards: 8,
            cold_start: ColdStartSpec::a40_7b(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn status(in_use: usize, queued: usize, work: f64, phase: LifecyclePhase) -> ShardStatus {
        ShardStatus {
            view: ShardView {
                in_use,
                queued,
                slots: Some(1),
                work,
                queued_tokens: queued as u64 * 50,
                admitting: phase == LifecyclePhase::Warm,
            },
            phase,
        }
    }

    fn view(now: f64, shards: &[ShardStatus]) -> FleetView<'_> {
        FleetView {
            now,
            shards,
            slots_per_shard: Some(1),
            min_shards: 1,
            max_shards: 8,
            prefill_tokens_per_sec: None,
        }
    }

    /// A continuous-batching fleet view: the token rate is set and the
    /// policies must read backlog in tokens.
    fn token_view<'a>(now: f64, shards: &'a [ShardStatus], rate: f64) -> FleetView<'a> {
        FleetView {
            prefill_tokens_per_sec: Some(rate),
            ..view(now, shards)
        }
    }

    #[test]
    fn fleet_view_counts_exclude_retired() {
        let shards = vec![
            status(1, 2, 3.0, LifecyclePhase::Warm),
            status(0, 4, 5.0, LifecyclePhase::Cold),
            status(1, 0, 1.0, LifecyclePhase::Draining),
            status(0, 0, 0.0, LifecyclePhase::Retired),
        ];
        let v = view(0.0, &shards);
        assert_eq!(v.warm_count(), 1);
        assert_eq!(v.cold_count(), 1);
        assert_eq!(v.provisioned_count(), 2);
        assert_eq!(v.outstanding(), 8);
        assert!((v.outstanding_work() - 9.0).abs() < 1e-12);
    }

    #[test]
    fn reactive_scales_out_after_sustained_overload_only() {
        let mut rng = Rng::new(1);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.25,
            sustain: 2,
            cooldown: 0.0,
            max_step: 8,
        });
        let hot = vec![status(1, 9, 12.0, LifecyclePhase::Warm)];
        // First overloaded evaluation: streak building, no action yet.
        assert_eq!(p.evaluate(&view(0.0, &hot), &mut rng), ScaleAction::Hold);
        // Second: sustained — scale out toward outstanding/watermark.
        match p.evaluate(&view(1.0, &hot), &mut rng) {
            ScaleAction::ScaleOut { shards } => assert_eq!(shards, 4), // ceil(10/2)-1
            other => panic!("expected scale-out, got {other:?}"),
        }
    }

    #[test]
    fn reactive_blip_resets_streak() {
        let mut rng = Rng::new(2);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.25,
            sustain: 2,
            cooldown: 0.0,
            max_step: 8,
        });
        let hot = vec![status(1, 9, 12.0, LifecyclePhase::Warm)];
        let calm = vec![status(1, 0, 0.5, LifecyclePhase::Warm)];
        assert_eq!(p.evaluate(&view(0.0, &hot), &mut rng), ScaleAction::Hold);
        // Load dips back into the dead band: the overload streak resets.
        assert_eq!(p.evaluate(&view(1.0, &calm), &mut rng), ScaleAction::Hold);
        assert_eq!(p.evaluate(&view(2.0, &hot), &mut rng), ScaleAction::Hold);
    }

    #[test]
    fn reactive_cooldown_blocks_back_to_back_actions() {
        let mut rng = Rng::new(3);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.25,
            sustain: 1,
            cooldown: 10.0,
            max_step: 1,
        });
        let hot = vec![status(1, 9, 12.0, LifecyclePhase::Warm)];
        assert!(matches!(
            p.evaluate(&view(0.0, &hot), &mut rng),
            ScaleAction::ScaleOut { .. }
        ));
        // Still overloaded, but inside the cooldown window.
        assert_eq!(p.evaluate(&view(5.0, &hot), &mut rng), ScaleAction::Hold);
        assert!(matches!(
            p.evaluate(&view(10.5, &hot), &mut rng),
            ScaleAction::ScaleOut { .. }
        ));
    }

    #[test]
    fn reactive_scales_in_when_idle() {
        let mut rng = Rng::new(4);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 3.0,
            scale_in_per_shard: 0.5,
            sustain: 2,
            cooldown: 0.0,
            max_step: 2,
        });
        let idle = vec![
            status(0, 0, 0.0, LifecyclePhase::Warm),
            status(0, 0, 0.0, LifecyclePhase::Warm),
            status(0, 0, 0.0, LifecyclePhase::Warm),
        ];
        assert_eq!(p.evaluate(&view(0.0, &idle), &mut rng), ScaleAction::Hold);
        assert_eq!(
            p.evaluate(&view(1.0, &idle), &mut rng),
            ScaleAction::ScaleIn { shards: 1 }
        );
    }

    #[test]
    fn reactive_counts_cold_shards_as_provisioned() {
        let mut rng = Rng::new(5);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.25,
            sustain: 1,
            cooldown: 0.0,
            max_step: 8,
        });
        // 1 warm + 4 cold shards against 10 outstanding: per-shard load is
        // 2.0, NOT 10.0 — the in-flight warm-ups must suppress re-firing.
        let ramping = vec![
            status(1, 9, 12.0, LifecyclePhase::Warm),
            status(0, 0, 0.0, LifecyclePhase::Cold),
            status(0, 0, 0.0, LifecyclePhase::Cold),
            status(0, 0, 0.0, LifecyclePhase::Cold),
            status(0, 0, 0.0, LifecyclePhase::Cold),
        ];
        assert_eq!(p.evaluate(&view(0.0, &ramping), &mut rng), ScaleAction::Hold);
    }

    /// At the band edge, a would-be action is suppressed entirely — it
    /// must NOT consume the cooldown a genuine action will need later.
    #[test]
    fn band_edge_actions_do_not_burn_cooldown() {
        let mut rng = Rng::new(8);
        let mut p = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.5,
            sustain: 1,
            cooldown: 10.0,
            max_step: 4,
        });
        let idle = vec![status(0, 0, 0.0, LifecyclePhase::Warm)];
        let hot = vec![status(1, 9, 12.0, LifecyclePhase::Warm)];
        fn at_min(shards: &[ShardStatus]) -> FleetView<'_> {
            FleetView {
                now: 0.0,
                shards,
                slots_per_shard: Some(1),
                min_shards: 1,
                max_shards: 8,
                prefill_tokens_per_sec: None,
            }
        }
        // Idle at warm == min: ScaleIn would be clamped, so Hold.
        let mut v = at_min(&idle);
        assert_eq!(p.evaluate(&v, &mut rng), ScaleAction::Hold);
        // A burst right after must scale out immediately — the swallowed
        // scale-in did not start the 10 s cooldown.
        v = at_min(&hot);
        v.now = 1.0;
        assert!(matches!(
            p.evaluate(&v, &mut rng),
            ScaleAction::ScaleOut { .. }
        ));
        // Symmetric guard: overloaded at provisioned == max emits Hold.
        let mut q = Reactive::new(ReactiveConfig {
            scale_out_per_shard: 2.0,
            scale_in_per_shard: 0.5,
            sustain: 1,
            cooldown: 10.0,
            max_step: 4,
        });
        let mut w = at_min(&hot);
        w.max_shards = 1;
        assert_eq!(q.evaluate(&w, &mut rng), ScaleAction::Hold);
    }

    #[test]
    fn ttft_target_scales_out_on_predicted_breach() {
        let mut rng = Rng::new(6);
        let mut p = TtftTarget::new(TtftTargetConfig {
            target_delay_s: 2.0,
            scale_in_margin: 0.5,
            cooldown: 0.0,
            max_step: 8,
        });
        // 12 s of outstanding work on one single-slot shard: predicted
        // delay 12 s ≫ 2 s target ⇒ need ceil(12/2)=6 shards, +5.
        let hot = vec![status(1, 8, 12.0, LifecyclePhase::Warm)];
        assert_eq!(
            p.evaluate(&view(0.0, &hot), &mut rng),
            ScaleAction::ScaleOut { shards: 5 }
        );
    }

    #[test]
    fn ttft_target_scales_in_only_with_margin() {
        let mut rng = Rng::new(7);
        let mut p = TtftTarget::new(TtftTargetConfig {
            target_delay_s: 2.0,
            scale_in_margin: 0.5,
            cooldown: 0.0,
            max_step: 4,
        });
        // Two warm shards, 1.8 s of work: predicted 0.9 s (under target),
        // but at one shard it would be 1.8 s > 1.0 s margin ⇒ hold.
        let busyish = vec![
            status(1, 0, 0.9, LifecyclePhase::Warm),
            status(1, 0, 0.9, LifecyclePhase::Warm),
        ];
        assert_eq!(p.evaluate(&view(0.0, &busyish), &mut rng), ScaleAction::Hold);
        // Nearly idle: safe to drain one.
        let idle = vec![
            status(0, 0, 0.4, LifecyclePhase::Warm),
            status(0, 0, 0.0, LifecyclePhase::Warm),
        ];
        assert_eq!(
            p.evaluate(&view(1.0, &idle), &mut rng),
            ScaleAction::ScaleIn { shards: 1 }
        );
    }

    /// Continuous batching re-derives the queue-depth signal from the
    /// token backlog: a fleet whose *request* count looks calm but whose
    /// queued prompt tokens are deep must trigger reactive scale-out —
    /// and vice versa, a shallow token backlog holds even with many
    /// small queued requests.
    #[test]
    fn reactive_token_backlog_signal_under_continuous_batching() {
        let mut rng = Rng::new(9);
        let cfg = ReactiveConfig {
            scale_out_per_shard: 2.0, // backlog-seconds per shard
            scale_in_per_shard: 0.25,
            sustain: 1,
            cooldown: 0.0,
            max_step: 8,
        };
        // One queued request of 2 000 tokens at 100 tok/s = 20 s of
        // backlog per shard ≫ 2 s watermark.
        let mut deep = vec![status(1, 1, 1.0, LifecyclePhase::Warm)];
        deep[0].view.queued_tokens = 2000;
        let mut p = Reactive::new(cfg);
        match p.evaluate(&token_view(0.0, &deep, 100.0), &mut rng) {
            ScaleAction::ScaleOut { shards } => {
                // desired = ceil(20 / 2) = 10, minus 1 provisioned, cap 8.
                assert_eq!(shards, 8);
            }
            other => panic!("deep token backlog must scale out, got {other:?}"),
        }
        // Nine queued requests of 10 tokens each = 0.9 s of backlog:
        // under the watermark even though the request count (9 per
        // shard) would have fired the legacy signal.
        let mut shallow = vec![status(1, 9, 12.0, LifecyclePhase::Warm)];
        shallow[0].view.queued_tokens = 90;
        let mut q = Reactive::new(cfg);
        assert_eq!(
            q.evaluate(&token_view(0.0, &shallow, 100.0), &mut rng),
            ScaleAction::Hold,
            "a shallow token backlog must not scale out"
        );
        // The same view under slot semantics DOES fire (legacy signal
        // unchanged).
        let mut r = Reactive::new(cfg);
        assert!(matches!(
            r.evaluate(&view(0.0, &shallow), &mut rng),
            ScaleAction::ScaleOut { .. }
        ));
        // Decode saturation (review fix): a deep batch with an EMPTY
        // admission queue must still trigger scale-out — the token gate
        // admits freely, so batch depth is the only congestion signal
        // left.
        let mut saturated = vec![status(12, 0, 18.0, LifecyclePhase::Warm)];
        saturated[0].view.queued_tokens = 0;
        let mut s = Reactive::new(cfg);
        match s.evaluate(&token_view(0.0, &saturated, 100.0), &mut rng) {
            ScaleAction::ScaleOut { shards } => {
                // demand = max(0, 12) = 12 → desired ceil(12/2) = 6, +5.
                assert_eq!(shards, 5);
            }
            other => panic!("deep batch must scale out, got {other:?}"),
        }
    }

    /// TTFT-target under continuous batching predicts admission delay
    /// from the token backlog over the admission rate.
    #[test]
    fn ttft_target_token_backlog_predictor() {
        let mut rng = Rng::new(10);
        let mut p = TtftTarget::new(TtftTargetConfig {
            target_delay_s: 2.0,
            scale_in_margin: 0.5,
            cooldown: 0.0,
            max_step: 8,
        });
        // 1 200 queued tokens at 100 tok/s = 12 s predicted on one
        // shard: need ceil(12/2) = 6 shards, +5.
        let mut hot = vec![status(1, 3, 0.5, LifecyclePhase::Warm)];
        hot[0].view.queued_tokens = 1200;
        assert_eq!(
            p.evaluate(&token_view(0.0, &hot, 100.0), &mut rng),
            ScaleAction::ScaleOut { shards: 5 }
        );
        // Empty backlog on two warm shards: scale-in is safe (predicted
        // delay 0 with margin to spare).
        let mut idle = vec![
            status(1, 0, 0.4, LifecyclePhase::Warm),
            status(0, 0, 0.0, LifecyclePhase::Warm),
        ];
        idle[0].view.queued_tokens = 0;
        idle[1].view.queued_tokens = 0;
        assert_eq!(
            p.evaluate(&token_view(1.0, &idle, 100.0), &mut rng),
            ScaleAction::ScaleIn { shards: 1 }
        );
        // Decode saturation (review fix): with no admission backlog but
        // 12 s of in-batch service outstanding, the predictor must
        // still see the congestion and scale out.
        let mut deep = vec![status(10, 0, 12.0, LifecyclePhase::Warm)];
        deep[0].view.queued_tokens = 0;
        let mut q = TtftTarget::new(TtftTargetConfig {
            target_delay_s: 2.0,
            scale_in_margin: 0.5,
            cooldown: 0.0,
            max_step: 8,
        });
        assert_eq!(
            q.evaluate(&token_view(2.0, &deep, 100.0), &mut rng),
            ScaleAction::ScaleOut { shards: 5 },
            "decode saturation must stay visible through outstanding work"
        );
        // Helper sanity: the backlog aggregates live shards only.
        let mixed = vec![
            status(0, 2, 0.0, LifecyclePhase::Warm),
            status(0, 4, 0.0, LifecyclePhase::Retired),
        ];
        let v = token_view(0.0, &mixed, 100.0);
        assert_eq!(v.queued_prompt_tokens(), 100);
        assert_eq!(v.queued_backlog_seconds(), Some(1.0));
        assert_eq!(v.in_service(), 0);
        assert_eq!(view(0.0, &mixed).queued_backlog_seconds(), None);
    }

    #[test]
    fn cold_start_spec_delay_and_parse_roundtrip() {
        assert_eq!(ColdStartSpec::Fixed(2.5).delay(), 2.5);
        assert_eq!(ColdStartSpec::Fixed(-1.0).delay(), 0.0);
        let a40 = ColdStartSpec::a40_7b();
        assert!((a40.delay() - ColdStartProfile::a40().load_time(7.0)).abs() < 1e-12);
        for s in ["fixed:2.5", "rtx3060:3", "a40:7", "rtx3060", "a40", "fixed:0"] {
            let spec = ColdStartSpec::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            assert!(spec.delay() >= 0.0);
        }
        assert!(ColdStartSpec::parse("nope").is_none());
        assert!(ColdStartSpec::parse("fixed:abc").is_none());
        assert_eq!(ColdStartSpec::parse("a40:7B").unwrap().delay(), a40.delay());
    }

    #[test]
    fn kind_parse_build_labels() {
        for (s, label) in [
            ("none", "none"),
            ("reactive", "reactive"),
            ("ttft", "ttft-target"),
            ("ttft-target", "ttft-target"),
        ] {
            let kind = AutoscalerKind::parse(s).unwrap();
            assert_eq!(kind.label(), label);
            assert_eq!(kind.to_string(), label);
            match kind.build() {
                Some(p) => assert_eq!(p.name(), label),
                None => assert_eq!(kind, AutoscalerKind::None),
            }
        }
        assert!(AutoscalerKind::parse("bogus").is_none());
        assert!(AutoscalerKind::None.build().is_none());
    }

    #[test]
    fn config_normalization_clamps_degenerate_values() {
        let cfg = AutoscaleConfig {
            kind: AutoscalerKind::Reactive(ReactiveConfig::default()),
            eval_interval: 0.0,
            min_shards: 0,
            max_shards: 0,
            cold_start: ColdStartSpec::Fixed(1.0),
        }
        .normalized();
        assert!(cfg.eval_interval > 0.0);
        assert_eq!(cfg.min_shards, 1);
        assert_eq!(cfg.max_shards, 1);
    }
}
