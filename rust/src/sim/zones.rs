//! Zone-partitioned fleet simulation: one logical cell split into Z
//! independent zones, run on scoped worker threads, merged
//! bit-reproducibly.
//!
//! A zone is a full [`FleetConfig`] fleet — its own shards, balancer,
//! autoscaler, batching mode, and an optional zone-wide RTT offset
//! (geo placement) — serving a deterministic round-robin slice of the
//! trace. Zones share nothing at run time, so they parallelize
//! perfectly across cores via [`crate::util::par::par_map`]; the
//! determinism contract (pinned by `tests/integration.rs` and the
//! migration-storm property) is:
//!
//! * **Thread-count invariance.** Every per-zone RNG stream derives
//!   from the zone *id* (never thread identity), and merged output is
//!   assembled in zone order, so results are byte-identical for any
//!   `DISCO_THREADS` — including fully serial.
//! * **Z=1 is the plain fleet.** Zone 0's seed mix is the identity and
//!   the identity partition is the whole trace, so a single-zone run
//!   is byte-identical to [`run_fleet`] on the same config.
//!
//! Cross-zone events (balancing, failover, migration *between* zones)
//! are deliberately out of scope: zones would then need a shared event
//! clock, which serializes the loop. The merge layer is the substrate
//! the geo-distribution direction builds on.

use crate::coordinator::policy::Policy;
use crate::metrics::LoadReport;
use crate::sim::engine::Scenario;
use crate::sim::fleet::{run_fleet, FleetConfig, FleetOutcome};
use crate::trace::{Request, Trace};
use crate::util::par::par_map;

/// One zone of a [`ZonedFleetConfig`]: a full fleet plus a zone-wide
/// extra RTT (seconds) added onto every shard of the zone — last-hop /
/// cross-region placement, the knob the per-shard `shard_rtts` table
/// expresses within a zone.
#[derive(Clone, Debug)]
pub struct ZoneConfig {
    pub fleet: FleetConfig,
    pub rtt_offset: f64,
}

impl ZoneConfig {
    pub fn new(fleet: FleetConfig) -> ZoneConfig {
        ZoneConfig {
            fleet,
            rtt_offset: 0.0,
        }
    }
}

/// Z independent zones, each a full fleet serving `1/Z` of the trace.
#[derive(Clone, Debug)]
pub struct ZonedFleetConfig {
    pub zones: Vec<ZoneConfig>,
}

impl ZonedFleetConfig {
    /// Z copies of the same fleet config (the homogeneous grid cell).
    pub fn uniform(z: usize, fleet: FleetConfig) -> ZonedFleetConfig {
        ZonedFleetConfig {
            zones: vec![ZoneConfig::new(fleet); z.max(1)],
        }
    }

    /// Append a heterogeneous zone.
    pub fn with_zone(mut self, zone: ZoneConfig) -> ZonedFleetConfig {
        self.zones.push(zone);
        self
    }

    /// Set per-zone RTT offsets (shorter than Z leaves the rest at 0).
    pub fn with_zone_rtts(mut self, rtts: &[f64]) -> ZonedFleetConfig {
        for (z, &off) in rtts.iter().enumerate().take(self.zones.len()) {
            self.zones[z].rtt_offset = off;
        }
        self
    }

    pub fn zone_count(&self) -> usize {
        self.zones.len()
    }
}

/// A zoned run's result: the merged fleet-wide outcome plus the
/// per-zone load breakdown. The breakdown is carried *alongside* the
/// merged [`LoadReport`] (not inside it) so a Z=1 merged report stays
/// bit-identical to the plain fleet's.
#[derive(Clone, Debug)]
pub struct ZonedOutcome {
    /// Fleet-wide outcome: records in `(arrival, zone, seq)` order,
    /// load folded via [`LoadReport::merge_zones`].
    pub merged: FleetOutcome,
    /// Each zone's own load report (times relative to the zone's first
    /// arrival), in zone order.
    pub zone_loads: Vec<LoadReport>,
}

/// Zone z's RNG seed: the [`crate::experiments::common::CellSeed`]
/// `mix_u64` fold of the zone id into the scenario seed — content-
/// derived, never thread identity. Zone 0's mix is the identity
/// (`0.rotate_left(17) * φ = 0`), which is exactly what makes a Z=1
/// zoned run byte-identical to the unzoned fleet.
pub fn zone_seed(base: u64, zone: u64) -> u64 {
    base ^ zone.rotate_left(17).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Deterministic round-robin partition: request at trace position `i`
/// lands in zone `i % Z`, keeping its id and arrival time. Every
/// sub-trace is therefore still arrival-sorted (a subsequence of a
/// sorted list), and Z=1 is the identity partition.
pub fn partition_trace(trace: &Trace, z: usize) -> Vec<Trace> {
    let z = z.max(1);
    let mut parts: Vec<Vec<Request>> = (0..z)
        .map(|_| Vec::with_capacity(trace.len() / z + 1))
        .collect();
    for (i, r) in trace.requests.iter().enumerate() {
        parts[i % z].push(*r);
    }
    parts
        .into_iter()
        .map(|reqs| Trace::new(&trace.name, reqs))
        .collect()
}

/// Run a trace across Z independent zones on scoped worker threads and
/// merge the outcomes. See the module docs for the determinism
/// contract; `DISCO_THREADS` bounds the worker count without ever
/// changing the result.
pub fn run_zoned_fleet(
    scenario: &Scenario,
    trace: &Trace,
    policy: &Policy,
    zoned: &ZonedFleetConfig,
) -> ZonedOutcome {
    assert!(!zoned.zones.is_empty(), "a zoned fleet needs at least one zone");
    let z = zoned.zones.len();
    let sub_traces = partition_trace(trace, z);

    // Per-zone inputs are fully materialized up front — seed mixed from
    // the zone id, the zone RTT offset folded into the shard RTT table —
    // so the worker closure is a pure `run_fleet` call.
    let cells: Vec<(Scenario, Trace, FleetConfig)> = zoned
        .zones
        .iter()
        .zip(sub_traces.iter())
        .enumerate()
        .map(|(zi, (zone, sub))| {
            let mut sc = scenario.clone();
            sc.cfg.seed = zone_seed(scenario.cfg.seed, zi as u64);
            let mut fleet = zone.fleet.clone();
            if zone.rtt_offset != 0.0 {
                // Fold the zone offset onto every shard (pad the table
                // to the shard count first). A zero offset leaves the
                // config untouched, preserving Z=1 byte-parity.
                fleet.shard_rtts.resize(fleet.shards.max(1), 0.0);
                for rtt in &mut fleet.shard_rtts {
                    *rtt += zone.rtt_offset;
                }
            }
            (sc, sub.clone(), fleet)
        })
        .collect();

    let outcomes: Vec<FleetOutcome> =
        par_map(&cells, |_, (sc, sub, fleet)| run_fleet(sc, sub, policy, fleet));

    // --- Merge. Every LoadReport time is relative to its own run's
    // first arrival, so each zone carries its t0 offset into the fold.
    let global_t0 = trace.requests.first().map_or(0.0, |r| r.arrival);
    let parts: Vec<(LoadReport, f64)> = outcomes
        .iter()
        .zip(sub_traces.iter())
        .map(|(out, sub)| {
            let t0 = sub.requests.first().map_or(global_t0, |r| r.arrival);
            (out.load.clone(), t0 - global_t0)
        })
        .collect();
    let load = LoadReport::merge_zones(&parts);

    // Records re-sorted by the stable (arrival, zone, seq) key: zones
    // are concatenated in zone order with each zone's records already
    // in sub-trace (seq) order, so a *stable* sort on arrival alone
    // realizes the full key. For Z=1 the input is already sorted and
    // the sort is the identity permutation — byte-parity with
    // `run_fleet` holds structurally, not by luck.
    let mut keyed: Vec<(f64, crate::metrics::RequestRecord)> = outcomes
        .into_iter()
        .zip(sub_traces.iter())
        .flat_map(|(out, sub)| {
            out.records
                .into_iter()
                .zip(sub.requests.iter().map(|r| r.arrival))
                .map(|(rec, arr)| (arr, rec))
                .collect::<Vec<_>>()
        })
        .collect();
    keyed.sort_by(|a, b| a.0.total_cmp(&b.0));
    let records = keyed.into_iter().map(|(_, rec)| rec).collect();

    ZonedOutcome {
        merged: FleetOutcome { records, load },
        zone_loads: parts.into_iter().map(|(r, _)| r).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyKind;
    use crate::cost::unified::Constraint;
    use crate::profiles::{DeviceProfile, ServerProfile};
    use crate::sim::balancer::BalancerKind;
    use crate::sim::engine::SimConfig;
    use crate::trace::generator::WorkloadSpec;

    fn scenario(seed: u64) -> Scenario {
        Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn zone_seed_mix_is_identity_at_zone_zero_and_distinct_otherwise() {
        assert_eq!(zone_seed(0xD15C0, 0), 0xD15C0);
        let seeds: Vec<u64> = (0..8).map(|z| zone_seed(0xD15C0, z)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "zones {i} and {j} collide");
            }
        }
    }

    #[test]
    fn partition_is_round_robin_and_preserves_ids_and_order() {
        let trace = WorkloadSpec::alpaca(10).generate(3);
        let parts = partition_trace(&trace, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(Trace::len).sum::<usize>(), 10);
        assert_eq!(parts[0].len(), 4); // positions 0,3,6,9
        for (z, part) in parts.iter().enumerate() {
            for (j, r) in part.requests.iter().enumerate() {
                assert_eq!(r.id, trace.requests[z + j * 3].id);
            }
            // Still arrival-sorted.
            for w in part.requests.windows(2) {
                assert!(w[0].arrival <= w[1].arrival);
            }
        }
        // Z=1 is the identity partition.
        let whole = partition_trace(&trace, 1);
        assert_eq!(whole.len(), 1);
        assert_eq!(whole[0].requests.len(), trace.requests.len());
        assert_eq!(whole[0].requests[5].id, trace.requests[5].id);
    }

    /// The acceptance pin at module scope: a single-zone run is
    /// byte-identical to the plain fleet under every balancer.
    #[test]
    fn single_zone_is_byte_identical_to_run_fleet_under_every_balancer() {
        let sc = scenario(0xD15C0);
        let trace = WorkloadSpec::alpaca(200).generate(7);
        let policy = Policy::simple(PolicyKind::StochD, 0.9, true);
        for balancer in BalancerKind::all() {
            let fleet = FleetConfig::sharded(3, 2, balancer);
            let plain = run_fleet(&sc, &trace, &policy, &fleet);
            let zoned = run_zoned_fleet(
                &sc,
                &trace,
                &policy,
                &ZonedFleetConfig::uniform(1, fleet.clone()),
            );
            assert_eq!(plain.records, zoned.merged.records, "{balancer:?}");
            assert_eq!(
                format!("{:?}", plain.load),
                format!("{:?}", zoned.merged.load),
                "{balancer:?}"
            );
            assert_eq!(zoned.zone_loads.len(), 1);
        }
    }

    /// Scalars decompose as the sum of their zones, and each zone's
    /// slice replays independently (content-derived seeding).
    #[test]
    fn zoned_run_decomposes_and_zones_replay_in_isolation() {
        let sc = scenario(42);
        let trace = WorkloadSpec::alpaca(120).generate(11);
        let policy = Policy::simple(PolicyKind::StochS, 0.5, true);
        let fleet = FleetConfig::sharded(2, 1, BalancerKind::JoinShortestQueue);
        let zoned = run_zoned_fleet(
            &sc,
            &trace,
            &policy,
            &ZonedFleetConfig::uniform(3, fleet.clone()),
        );
        assert_eq!(zoned.merged.records.len(), 120);
        assert_eq!(zoned.zone_loads.len(), 3);
        let m = &zoned.merged.load;
        assert_eq!(
            m.events_processed,
            zoned.zone_loads.iter().map(|l| l.events_processed).sum::<u64>()
        );
        let busy: f64 = zoned.zone_loads.iter().map(|l| l.server_busy_seconds).sum();
        assert!((m.server_busy_seconds - busy).abs() < 1e-12);
        assert_eq!(m.shards.len(), 6, "2 shards × 3 zones concatenate");
        // Merged records are globally arrival-sorted with ids intact.
        let mut ids: Vec<u64> = zoned.merged.records.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 120, "no record lost or duplicated");
        // Zone 1's slice reproduces bit-for-bit in isolation.
        let subs = partition_trace(&trace, 3);
        let mut sc1 = sc.clone();
        sc1.cfg.seed = zone_seed(sc.cfg.seed, 1);
        let solo = run_fleet(&sc1, &subs[1], &policy, &fleet);
        assert_eq!(
            format!("{:?}", solo.load),
            format!("{:?}", zoned.zone_loads[1])
        );
    }

    /// A zone-wide RTT offset only shifts that zone's shards; offset 0
    /// leaves the config (and thus the records) untouched.
    #[test]
    fn zone_rtt_offset_applies_per_zone() {
        let sc = scenario(9);
        let trace = WorkloadSpec::alpaca(80).generate(5);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let fleet = FleetConfig::sharded(2, 2, BalancerKind::RoundRobin);
        let base = run_zoned_fleet(
            &sc,
            &trace,
            &policy,
            &ZonedFleetConfig::uniform(2, fleet.clone()),
        );
        let offset = run_zoned_fleet(
            &sc,
            &trace,
            &policy,
            &ZonedFleetConfig::uniform(2, fleet.clone()).with_zone_rtts(&[0.0, 0.25]),
        );
        // Zone 0 (offset 0) is untouched…
        assert_eq!(
            format!("{:?}", base.zone_loads[0]),
            format!("{:?}", offset.zone_loads[0])
        );
        // …zone 1's server-side first tokens all shifted later.
        let zone1_ids: Vec<u64> = partition_trace(&trace, 2)[1]
            .requests
            .iter()
            .map(|r| r.id)
            .collect();
        let ttft_of = |o: &ZonedOutcome, id: u64| {
            o.merged
                .records
                .iter()
                .find(|r| r.id == id)
                .map(|r| r.ttft)
                .unwrap()
        };
        let mut shifted = 0;
        for &id in &zone1_ids {
            let b = ttft_of(&base, id);
            let o = ttft_of(&offset, id);
            assert!(o >= b - 1e-12, "offset can only delay first tokens");
            if o > b + 1e-12 {
                shifted += 1;
            }
        }
        assert!(shifted > 0, "a 250 ms zone offset must move some TTFTs");
    }
}
