//! Continuous batching within a shard (the vLLM/Orca serving model).
//!
//! The slot model the fleet shipped with (PRs 1–4) holds one admission
//! slot per stream for the stream's whole lifetime — prefill *and*
//! decode — so a shard's concurrency is a fixed small integer and
//! admission blocks on decode completions. Real serving stacks do not
//! work that way: Orca schedules at iteration granularity and vLLM
//! admits prefills against a token budget while decode streams share
//! the accelerator in one continuous batch, paying per-token latency
//! that grows with the batch size. This module holds the *configuration*
//! side of that model; the mechanics (tick events, token-gated
//! admission, batch-occupancy decode slowdown) live in the
//! [`crate::sim::fleet`] event loop and [`crate::sim::engine`].
//!
//! Three admission regimes, selected by [`BatchingMode`] on
//! `FleetConfig::batching`:
//!
//! * [`BatchingMode::SlotLegacy`] (default) — the historical bounded
//!   slot pool, byte-identical to the pre-batching fleet under every
//!   balancer × autoscaler (no tick events are scheduled, no slowdown
//!   factor is applied).
//! * [`BatchingMode::Continuous`] — prefill admission is gated by a
//!   prompt-token budget replenished every scheduling tick
//!   ([`ContinuousBatchConfig::prefill_tokens_per_tick`] /
//!   [`ContinuousBatchConfig::tick_interval`]); admitted decode streams
//!   share the shard's batch, and each stream's inter-token gaps are
//!   scaled by [`BatchLatencyCurve::slowdown`] (see the pricing
//!   contract below).
//! * [`BatchingMode::PagedKv`] — admission is gated by the shard's
//!   paged KV block pool ([`crate::sim::kv::KvConfig`]): prefills
//!   allocate pages, decode grows page usage, memory pressure preempts
//!   the lowest-priority stream, and prefix-cache hits skip the cached
//!   fraction of prefill. The tick/batch-pricing machinery is shared
//!   with `Continuous`; only the admission signal differs.
//!
//! # Decode pricing: join-time vs iteration-level
//!
//! Under the historical [`PricingMode::JoinTime`] (the default), a
//! stream's decode pace is priced at the batch size observed when it is
//! admitted (including itself); streams that join *later* see the
//! larger batch, but an already-running stream is never repriced
//! mid-decode. That keeps the engine's one-shot trajectory resolution
//! intact, at the cost of underestimating slowdown during a ramp (and
//! overestimating it during a drain).
//!
//! [`PricingMode::IterationLevel`] removes the approximation. The
//! contract:
//!
//! * **When repricing fires.** Whenever a shard's batch *size* changes
//!   — a prefill admits, a stream departs, KV memory pressure preempts
//!   a victim, a migrated-in tail books onto the shard — every
//!   still-decoding, non-migrated server stream on that shard whose
//!   current slowdown differs from `slowdown(new batch)` is repriced.
//!   Same-size composition changes are skipped: the curve depends only
//!   on the batch size, so pricing is unchanged by construction.
//! * **Which tokens are re-stamped.** Only *pending* generation times
//!   move. Tokens already emitted at the reprice instant keep their
//!   times; the in-flight gap is split piecewise — the elapsed portion
//!   stays priced at the old slowdown, the remainder is re-scaled by
//!   `new/old` — and every later gap re-scales fully. Delivery
//!   smoothing, the stream's release event, shard busy-seconds, and
//!   cost metering are all finalized from the repriced timeline when
//!   the stream completes (deferred finalization in `sim/fleet.rs`).
//! * **Interaction with KV preemption's stretched gap.** A preempted
//!   stream's in-flight gap is stretched by its re-prefill delay; that
//!   stall is *not* decode and must not re-scale. Repricing therefore
//!   skips streams that are inside their preemption-suspension window;
//!   they re-enter pricing at the first batch change after the
//!   suspension ends. Migrated streams' committed handoff tails are
//!   likewise never repriced.
//! * **Inertness.** `SlotLegacy` schedules no ticks and prices nothing,
//!   `Flat` curves price every batch at exactly 1.0, and batches that
//!   never exceed one stream always price at 1.0 — in all three cases
//!   `IterationLevel` runs are byte-identical to `JoinTime` runs (a
//!   reprice only fires when the slowdown value actually changes).

use crate::sim::kv::KvConfig;

/// Per-token decode latency as a function of the shard's batch size.
///
/// `slowdown(b)` multiplies a stream's sampled inter-token gaps; it is
/// ≥ 1.0 and `slowdown(1) == 1.0`, so a lone stream reproduces the
/// profiled single-stream decode exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchLatencyCurve {
    /// No batch interference (an ideally parallel accelerator): every
    /// batch size decodes at the single-stream rate.
    Flat,
    /// Linear interference: `1 + alpha × (b − 1)` — every extra stream
    /// in the batch costs a fixed fraction of the single-stream gap.
    Linear {
        /// Marginal per-stream slowdown.
        alpha: f64,
    },
    /// Hardware-knee shape: batching is free up to `knee` streams
    /// (parallelism absorbs it), then grows linearly at `alpha` per
    /// stream — the memory-bandwidth-bound regime of a real GPU.
    Knee {
        /// Largest batch size served at the single-stream rate.
        knee: usize,
        /// Marginal per-stream slowdown beyond the knee.
        alpha: f64,
    },
}

impl BatchLatencyCurve {
    /// Multiplier on sampled inter-token gaps for a stream joining a
    /// batch of `batch` streams (including itself). Always ≥ 1.0;
    /// `batch ≤ 1` always maps to exactly 1.0.
    pub fn slowdown(&self, batch: usize) -> f64 {
        let extra = batch.saturating_sub(1) as f64;
        match *self {
            BatchLatencyCurve::Flat => 1.0,
            BatchLatencyCurve::Linear { alpha } => 1.0 + alpha.max(0.0) * extra,
            BatchLatencyCurve::Knee { knee, alpha } => {
                let beyond = batch.saturating_sub(knee.max(1)) as f64;
                1.0 + alpha.max(0.0) * beyond
            }
        }
    }

    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> String {
        match *self {
            BatchLatencyCurve::Flat => "flat".to_string(),
            BatchLatencyCurve::Linear { alpha } => format!("linear:{alpha}"),
            BatchLatencyCurve::Knee { knee, alpha } => format!("knee:{knee}:{alpha}"),
        }
    }

    /// Parse a CLI spelling: `flat`, `linear:ALPHA`, or `knee:K:ALPHA`
    /// (bare `linear` / `knee` take the defaults 0.05 / 8:0.05).
    /// Trailing fields are rejected — a typo'd arity must error, not
    /// silently run a different curve.
    pub fn parse(s: &str) -> Option<BatchLatencyCurve> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        let head = parts.next()?;
        let curve = match head {
            "flat" => BatchLatencyCurve::Flat,
            "linear" => {
                let alpha = match parts.next() {
                    None => 0.05,
                    Some(a) => a.parse::<f64>().ok()?,
                };
                BatchLatencyCurve::Linear { alpha }
            }
            "knee" => {
                let knee = match parts.next() {
                    None => 8,
                    Some(k) => k.parse::<usize>().ok()?,
                };
                let alpha = match parts.next() {
                    None => 0.05,
                    Some(a) => a.parse::<f64>().ok()?,
                };
                BatchLatencyCurve::Knee { knee, alpha }
            }
            _ => return None,
        };
        if parts.next().is_some() {
            return None;
        }
        Some(curve)
    }
}

impl std::fmt::Display for BatchLatencyCurve {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Tunables of the continuous-batching admission and decode model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContinuousBatchConfig {
    /// Prompt tokens a shard may admit per scheduling tick. A prompt
    /// longer than the whole per-tick budget is admitted when the tick's
    /// budget is untouched and consumes all of it (no chunked prefill
    /// yet — see ROADMAP), so oversized prompts cannot starve.
    pub prefill_tokens_per_tick: u32,
    /// Seconds between admission ticks (budget replenishment).
    pub tick_interval: f64,
    /// Optional cap on concurrently decoding streams per shard (`None`
    /// = unbounded; the latency curve is then the only brake). A §4.3
    /// migrated-in stream joins even a full batch — its handoff time is
    /// already committed.
    pub max_batch: Option<usize>,
    /// Per-token decode latency vs batch size.
    pub curve: BatchLatencyCurve,
}

impl ContinuousBatchConfig {
    /// Sustained prompt-token admission rate (tokens/second).
    pub fn tokens_per_sec(&self) -> f64 {
        self.prefill_tokens_per_tick as f64 / self.tick_interval
    }

    /// Clamp degenerate values (zero budget, non-positive tick) so the
    /// event loop can never stall on an un-replenishable budget.
    pub fn normalized(&self) -> ContinuousBatchConfig {
        ContinuousBatchConfig {
            prefill_tokens_per_tick: self.prefill_tokens_per_tick.max(1),
            tick_interval: if self.tick_interval > 0.0 {
                self.tick_interval
            } else {
                0.25
            },
            max_batch: self.max_batch.map(|m| m.max(1)),
            curve: self.curve,
        }
    }
}

impl Default for ContinuousBatchConfig {
    fn default() -> Self {
        ContinuousBatchConfig {
            prefill_tokens_per_tick: 128,
            tick_interval: 0.25,
            max_batch: None,
            curve: BatchLatencyCurve::Knee {
                knee: 8,
                alpha: 0.05,
            },
        }
    }
}

/// How a shard admits and serves concurrent streams.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum BatchingMode {
    /// The historical model: a bounded slot pool per shard, one slot
    /// held per stream for its whole lifetime. Byte-identical to the
    /// pre-batching fleet (the parity tests pin this under every
    /// balancer × autoscaler).
    #[default]
    SlotLegacy,
    /// Continuous batching: token-budget prefill admission + shared
    /// decode batch with a batch-size-dependent latency curve.
    Continuous(ContinuousBatchConfig),
    /// Paged KV admission: prefills allocate KV block-pool pages,
    /// decode grows page usage, pressure preempts, prefix-cache hits
    /// skip prefill (`sim/kv.rs`).
    PagedKv(KvConfig),
}

impl BatchingMode {
    /// Whether this mode is the continuous token-budget gate.
    pub fn is_continuous(&self) -> bool {
        matches!(self, BatchingMode::Continuous(_))
    }

    /// Whether this mode is the paged-KV gate.
    pub fn is_paged(&self) -> bool {
        matches!(self, BatchingMode::PagedKv(_))
    }

    /// Whether this mode schedules tick events and gated (unbounded)
    /// pools — everything except the legacy slot model.
    pub fn batched(&self) -> bool {
        !matches!(self, BatchingMode::SlotLegacy)
    }

    /// The continuous config, if any.
    pub fn continuous(&self) -> Option<&ContinuousBatchConfig> {
        match self {
            BatchingMode::Continuous(c) => Some(c),
            _ => None,
        }
    }

    /// The paged-KV config, if any.
    pub fn paged(&self) -> Option<&KvConfig> {
        match self {
            BatchingMode::PagedKv(k) => Some(k),
            _ => None,
        }
    }

    /// The scheduling-tick interval, when the mode schedules ticks
    /// (`Continuous` and `PagedKv` — `SlotLegacy` never ticks).
    pub fn tick_interval(&self) -> Option<f64> {
        match self {
            BatchingMode::SlotLegacy => None,
            BatchingMode::Continuous(c) => Some(c.tick_interval),
            BatchingMode::PagedKv(k) => Some(k.tick_interval),
        }
    }

    /// Sustained prefill-token admission rate of the mode's gate
    /// (tokens/second) — the signal the autoscaler's backlog estimate
    /// and the §4.3 re-prefill queue-delay estimate read. `None` for
    /// the slot model, whose admission is not token-denominated.
    pub fn admission_tokens_per_sec(&self) -> Option<f64> {
        match self {
            BatchingMode::SlotLegacy => None,
            BatchingMode::Continuous(c) => Some(c.tokens_per_sec()),
            BatchingMode::PagedKv(k) => Some(k.tokens_per_sec()),
        }
    }

    /// Short label used in tables and CSVs.
    pub fn label(&self) -> &'static str {
        match self {
            BatchingMode::SlotLegacy => "slot-legacy",
            BatchingMode::Continuous(_) => "continuous",
            BatchingMode::PagedKv(_) => "paged-kv",
        }
    }

    /// Clamp the gated modes' tunables; the legacy mode has none.
    pub fn normalized(&self) -> BatchingMode {
        match self {
            BatchingMode::SlotLegacy => BatchingMode::SlotLegacy,
            BatchingMode::Continuous(c) => BatchingMode::Continuous(c.normalized()),
            BatchingMode::PagedKv(k) => BatchingMode::PagedKv(k.normalized()),
        }
    }
}

impl std::fmt::Display for BatchingMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// How a gated shard prices decode against its batch-latency curve.
/// See the module-level "Decode pricing" contract. Irrelevant under
/// [`BatchingMode::SlotLegacy`], which never prices decode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PricingMode {
    /// Freeze each stream's slowdown at the batch size it joined (the
    /// historical approximation; never repriced mid-decode).
    #[default]
    JoinTime,
    /// Re-price every running stream's pending inter-token gaps
    /// whenever its shard's batch size changes.
    IterationLevel,
}

impl PricingMode {
    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            PricingMode::JoinTime => "join-time",
            PricingMode::IterationLevel => "iteration-level",
        }
    }

    /// Parse a CLI spelling (`join-time` / `iteration-level`).
    pub fn parse(s: &str) -> Option<PricingMode> {
        match s.to_ascii_lowercase().as_str() {
            "join-time" | "jointime" | "join" => Some(PricingMode::JoinTime),
            "iteration-level" | "iteration" | "repriced" => Some(PricingMode::IterationLevel),
            _ => None,
        }
    }
}

impl std::fmt::Display for PricingMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdown_is_one_for_lone_stream_and_monotone() {
        let curves = [
            BatchLatencyCurve::Flat,
            BatchLatencyCurve::Linear { alpha: 0.1 },
            BatchLatencyCurve::Knee {
                knee: 4,
                alpha: 0.2,
            },
        ];
        for curve in curves {
            assert_eq!(curve.slowdown(0), 1.0, "{curve}");
            assert_eq!(curve.slowdown(1), 1.0, "{curve}");
            let mut prev = 1.0;
            for b in 2..40 {
                let s = curve.slowdown(b);
                assert!(s >= prev, "{curve}: slowdown must be nondecreasing");
                assert!(s >= 1.0);
                prev = s;
            }
        }
    }

    #[test]
    fn flat_is_constant_and_knee_is_free_below_knee() {
        assert_eq!(BatchLatencyCurve::Flat.slowdown(100), 1.0);
        let knee = BatchLatencyCurve::Knee {
            knee: 8,
            alpha: 0.05,
        };
        for b in 1..=8 {
            assert_eq!(knee.slowdown(b), 1.0, "below the knee batching is free");
        }
        assert!((knee.slowdown(9) - 1.05).abs() < 1e-12);
        assert!((knee.slowdown(18) - 1.5).abs() < 1e-12);
        let lin = BatchLatencyCurve::Linear { alpha: 0.1 };
        assert!((lin.slowdown(11) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn negative_alpha_clamps_to_no_speedup() {
        // A mis-tuned curve must never make batching a speedup.
        let lin = BatchLatencyCurve::Linear { alpha: -0.5 };
        assert_eq!(lin.slowdown(16), 1.0);
        let knee = BatchLatencyCurve::Knee {
            knee: 2,
            alpha: -1.0,
        };
        assert_eq!(knee.slowdown(16), 1.0);
    }

    #[test]
    fn curve_parse_roundtrips_labels() {
        for s in ["flat", "linear:0.05", "knee:8:0.05", "linear", "knee"] {
            let c = BatchLatencyCurve::parse(s).unwrap_or_else(|| panic!("parse {s}"));
            assert_eq!(
                BatchLatencyCurve::parse(&c.label()),
                Some(c),
                "label must roundtrip for {s}"
            );
        }
        assert_eq!(BatchLatencyCurve::parse("flat"), Some(BatchLatencyCurve::Flat));
        assert_eq!(
            BatchLatencyCurve::parse("knee:4:0.2"),
            Some(BatchLatencyCurve::Knee {
                knee: 4,
                alpha: 0.2
            })
        );
        assert!(BatchLatencyCurve::parse("nope").is_none());
        assert!(BatchLatencyCurve::parse("linear:abc").is_none());
        // Trailing fields are arity errors, not silently dropped.
        assert!(BatchLatencyCurve::parse("flat:0.3").is_none());
        assert!(BatchLatencyCurve::parse("linear:0.05:oops").is_none());
        assert!(BatchLatencyCurve::parse("knee:8:0.05:2").is_none());
    }

    #[test]
    fn config_normalization_clamps_degenerate_values() {
        let cfg = ContinuousBatchConfig {
            prefill_tokens_per_tick: 0,
            tick_interval: 0.0,
            max_batch: Some(0),
            curve: BatchLatencyCurve::Flat,
        }
        .normalized();
        assert_eq!(cfg.prefill_tokens_per_tick, 1);
        assert!(cfg.tick_interval > 0.0);
        assert_eq!(cfg.max_batch, Some(1));
        let good = ContinuousBatchConfig::default();
        assert_eq!(good.normalized(), good, "sane configs are untouched");
        assert!((good.tokens_per_sec() - 512.0).abs() < 1e-9);
    }

    #[test]
    fn mode_labels_and_helpers() {
        assert_eq!(BatchingMode::default(), BatchingMode::SlotLegacy);
        assert!(!BatchingMode::SlotLegacy.is_continuous());
        assert!(BatchingMode::SlotLegacy.continuous().is_none());
        let c = BatchingMode::Continuous(ContinuousBatchConfig::default());
        assert!(c.is_continuous());
        assert_eq!(c.label(), "continuous");
        assert_eq!(BatchingMode::SlotLegacy.label(), "slot-legacy");
        assert_eq!(c.normalized(), c);
        let p = BatchingMode::PagedKv(KvConfig::default());
        assert!(p.is_paged() && !p.is_continuous());
        assert!(p.batched() && c.batched() && !BatchingMode::SlotLegacy.batched());
        assert_eq!(p.label(), "paged-kv");
        assert_eq!(p.normalized(), p);
        assert_eq!(p.tick_interval(), Some(0.25));
        assert_eq!(BatchingMode::SlotLegacy.tick_interval(), None);
        assert_eq!(BatchingMode::SlotLegacy.admission_tokens_per_sec(), None);
        assert_eq!(c.admission_tokens_per_sec(), Some(512.0));
        assert_eq!(p.admission_tokens_per_sec(), Some(1024.0));
        assert!(p.paged().is_some() && c.paged().is_none());
    }

    #[test]
    fn pricing_mode_defaults_labels_and_parse() {
        assert_eq!(PricingMode::default(), PricingMode::JoinTime);
        assert_eq!(PricingMode::JoinTime.label(), "join-time");
        assert_eq!(PricingMode::IterationLevel.label(), "iteration-level");
        for m in [PricingMode::JoinTime, PricingMode::IterationLevel] {
            assert_eq!(PricingMode::parse(m.label()), Some(m), "label roundtrip");
        }
        assert_eq!(PricingMode::parse("repriced"), Some(PricingMode::IterationLevel));
        assert!(PricingMode::parse("sometimes").is_none());
    }
}
