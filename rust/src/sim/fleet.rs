//! Discrete-event fleet simulator: N concurrent requests contending for a
//! bounded server and a single-flight device.
//!
//! The paper evaluates per-request (each request sees the profiled latency
//! distributions independently). At fleet scale the interesting effects
//! are *contention* effects: a server with a finite admission capacity
//! builds a queue as load rises, and the on-device model can only run one
//! inference at a time. This module adds exactly that, as a binary-heap
//! event loop over:
//!
//! * **Arrival** events — fork the request's RNG, draw its dispatch
//!   decision through the unchanged `coordinator::policy`, pre-draw its
//!   latency samples, and enqueue it on the resources it needs;
//! * **grant** transitions — a FIFO server pool with `server_slots`
//!   concurrent admissions and a FIFO single-flight device pool;
//! * **first-token probes** — when one endpoint produces its first token
//!   while the request is still *queued* on the other endpoint, the
//!   queued entry is cancelled (the §4.2 wait-time strategy extended
//!   across the fleet: nobody waits on a resource after the race is won);
//! * **release** events — slots free at stream end, handoff, or loser
//!   cancellation, admitting the next queued request.
//!
//! The per-request trajectory itself (race, cancellation, migration,
//! delivery smoothing, cost metering) is [`crate::sim::engine`]'s
//! [`resolve_request`] — one code path shared with the legacy replay,
//! which is the degenerate configuration [`FleetConfig::replay`]
//! (unlimited server pool). With that configuration the fleet loop is
//! byte-identical to the historical per-request engine: per-request RNG
//! streams are forked in trace order and all latency samples are
//! pre-drawn at arrival, so resolution timing cannot perturb them.
//!
//! Determinism: the heap orders events by `(time, sequence)` with
//! `f64::total_cmp`, so runs are bit-reproducible from `SimConfig.seed`.

use crate::coordinator::migration::MigrationPlanner;
use crate::coordinator::policy::Policy;
use crate::metrics::{LoadReport, RequestRecord};
use crate::sim::engine::{pre_draw, resolve_request, PreDrawn, ResourceTimes, Scenario};
use crate::stats::describe::Summary;
use crate::trace::Trace;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Fleet-level resource configuration.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Concurrent server admissions; `None` = unlimited (the paper's
    /// independent replay, where server TTFT already folds queueing in
    /// statistically).
    pub server_slots: Option<usize>,
    /// Model the single-flight device across requests.
    pub device_queueing: bool,
}

impl FleetConfig {
    /// The legacy per-request replay configuration.
    pub fn replay(device_queueing: bool) -> FleetConfig {
        FleetConfig {
            server_slots: None,
            device_queueing,
        }
    }

    /// A bounded-server fleet with single-flight device contention.
    pub fn bounded(server_slots: usize) -> FleetConfig {
        FleetConfig {
            server_slots: Some(server_slots.max(1)),
            device_queueing: true,
        }
    }
}

/// Result of a fleet run: per-request records (trace order) plus load
/// metrics.
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    pub records: Vec<RequestRecord>,
    pub load: LoadReport,
}

// ---------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EvKind {
    Arrival(usize),
    /// A server admission slot frees; admit the next queued request.
    ServerRelease,
    /// The device frees; grant it to the next queued request.
    DeviceRelease,
    /// The server produced its first token while the request was still
    /// queued for the device: cancel the device entry and resolve.
    ServerFirstProbe(usize),
    /// The device produced its first token while the request was still
    /// queued for server admission: cancel the server entry and resolve.
    DeviceFirstProbe(usize),
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------
// Resource pools
// ---------------------------------------------------------------------

/// FIFO pool with a (possibly unlimited) concurrency cap. Cancelled
/// entries are skipped lazily at pop time.
#[derive(Debug)]
struct Pool {
    cap: Option<usize>,
    in_use: usize,
    queue: VecDeque<usize>,
}

impl Pool {
    fn new(cap: Option<usize>) -> Pool {
        Pool {
            cap,
            in_use: 0,
            queue: VecDeque::new(),
        }
    }

    /// Try to acquire at `now`; queues and returns None when full.
    fn acquire(&mut self, i: usize) -> bool {
        match self.cap {
            None => true,
            Some(cap) if self.in_use < cap => {
                self.in_use += 1;
                true
            }
            _ => {
                self.queue.push_back(i);
                false
            }
        }
    }

    /// Release one unit; returns the next non-cancelled queued request to
    /// grant, if any (the unit transfers to it).
    fn release(&mut self, cancelled: &[bool]) -> Option<usize> {
        while let Some(j) = self.queue.pop_front() {
            if !cancelled[j] {
                return Some(j);
            }
        }
        self.in_use = self.in_use.saturating_sub(1);
        None
    }
}

// ---------------------------------------------------------------------
// The simulator
// ---------------------------------------------------------------------

#[derive(Debug)]
struct ReqState {
    pre: PreDrawn,
    rng: Rng,
    needs_server: bool,
    needs_device: bool,
    server_admit: Option<f64>,
    device_grant: Option<f64>,
    resolved: bool,
}

struct FleetSim<'a> {
    scenario: &'a Scenario,
    trace: &'a Trace,
    policy: &'a Policy,
    planner: MigrationPlanner,
    fleet: FleetConfig,
    heap: BinaryHeap<Event>,
    seq: u64,
    states: Vec<Option<ReqState>>,
    /// Queue-entry cancellation flags, indexed by request. These live
    /// outside `ReqState` (single source of truth) so `Pool::release`
    /// can consult them while the simulator is otherwise borrowed.
    server_cancelled: Vec<bool>,
    device_cancelled: Vec<bool>,
    server_pool: Pool,
    device_pool: Pool,
    records: Vec<Option<RequestRecord>>,
    server_delays: Vec<f64>,
    device_delays: Vec<f64>,
    server_busy: f64,
    device_busy: f64,
    horizon: f64,
}

impl<'a> FleetSim<'a> {
    fn push(&mut self, time: f64, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// Request `i`, borrowed for the trace lifetime (decoupled from
    /// `&self`, so the loop can mutate simulator state while holding it).
    fn req(&self, i: usize) -> &'a crate::trace::Request {
        &self.trace.requests[i]
    }

    fn run(mut self) -> FleetOutcome {
        // Fork per-request RNG streams in trace order (not event order):
        // this pins the root RNG sequence to the trace, matching the
        // legacy engine draw-for-draw.
        let trace = self.trace;
        let mut root = Rng::new(self.scenario.cfg.seed);
        let mut rngs: Vec<Option<Rng>> = trace
            .requests
            .iter()
            .map(|r| Some(root.fork(r.id)))
            .collect();
        for (i, req) in trace.requests.iter().enumerate() {
            self.push(req.arrival, EvKind::Arrival(i));
        }

        while let Some(ev) = self.heap.pop() {
            if ev.time.is_finite() {
                self.horizon = self.horizon.max(ev.time);
            }
            match ev.kind {
                EvKind::Arrival(i) => {
                    let req = self.req(i);
                    let mut rng = rngs[i].take().expect("arrival fires once");
                    let pre = pre_draw(
                        req,
                        self.policy,
                        &self.scenario.server,
                        &self.scenario.device,
                        &mut rng,
                    );
                    let needs_server = pre.decision.uses_server();
                    let needs_device = pre.decision.uses_device();
                    self.states[i] = Some(ReqState {
                        pre,
                        rng,
                        needs_server,
                        needs_device,
                        server_admit: None,
                        device_grant: None,
                        resolved: false,
                    });
                    if needs_server && self.server_pool.acquire(i) {
                        self.on_server_admit(i, ev.time);
                    }
                    if needs_device
                        && (!self.fleet.device_queueing || self.device_pool.acquire(i))
                    {
                        self.on_device_grant(i, ev.time);
                    }
                    self.try_resolve(i, ev.time);
                }
                EvKind::ServerRelease => {
                    let next = self.server_pool.release(&self.server_cancelled);
                    if let Some(j) = next {
                        self.on_server_admit(j, ev.time);
                        self.try_resolve(j, ev.time);
                    }
                }
                EvKind::DeviceRelease => {
                    let next = self.device_pool.release(&self.device_cancelled);
                    if let Some(j) = next {
                        self.on_device_grant(j, ev.time);
                        self.try_resolve(j, ev.time);
                    }
                }
                EvKind::ServerFirstProbe(i) => {
                    let pending = !self.device_cancelled[i] && {
                        let st = self.state(i);
                        !st.resolved && st.device_grant.is_none()
                    };
                    if pending {
                        // The server answered first: leave the device queue.
                        self.device_cancelled[i] = true;
                        self.try_resolve(i, ev.time);
                    }
                }
                EvKind::DeviceFirstProbe(i) => {
                    let pending = !self.server_cancelled[i] && {
                        let st = self.state(i);
                        !st.resolved && st.server_admit.is_none()
                    };
                    if pending {
                        // The device answered first: abandon the admission
                        // queue (the provider still bills the dispatched
                        // prompt; see `resolve_request`).
                        self.server_cancelled[i] = true;
                        self.try_resolve(i, ev.time);
                    }
                }
            }
        }

        let records: Vec<RequestRecord> = self
            .records
            .into_iter()
            .map(|r| r.expect("every request resolves"))
            .collect();
        // Horizon is measured from the first arrival, not absolute time
        // zero, so traces with a delayed start (e.g. session ramp-up) do
        // not dilute utilization with an idle prefix.
        let t0 = trace.requests.first().map_or(0.0, |r| r.arrival);
        let load = LoadReport {
            server_queue_delay: Summary::of(&self.server_delays),
            device_queue_delay: Summary::of(&self.device_delays),
            server_busy_seconds: self.server_busy,
            device_busy_seconds: self.device_busy,
            horizon: (self.horizon - t0).max(0.0),
            server_slots: self.fleet.server_slots,
        };
        FleetOutcome { records, load }
    }

    fn state(&self, i: usize) -> &ReqState {
        self.states[i].as_ref().expect("state exists after arrival")
    }

    fn state_mut(&mut self, i: usize) -> &mut ReqState {
        self.states[i].as_mut().expect("state exists after arrival")
    }

    fn on_server_admit(&mut self, i: usize, now: f64) {
        let arrival = self.trace.requests[i].arrival;
        let dev_cancelled = self.device_cancelled[i];
        let (sample, device_pending) = {
            let st = self.state_mut(i);
            st.server_admit = Some(now);
            (
                st.pre.server_sample.expect("server users have a sample"),
                st.needs_device && st.device_grant.is_none() && !dev_cancelled,
            )
        };
        self.server_delays.push((now - arrival).max(0.0));
        if device_pending {
            // First token lands at admit + intrinsic prefill; if the
            // device is still queued then, it is skipped (§4.2).
            self.push(now + sample, EvKind::ServerFirstProbe(i));
        }
    }

    fn on_device_grant(&mut self, i: usize, now: f64) {
        let req = self.req(i);
        let srv_cancelled = self.server_cancelled[i];
        let (dev_first_abs, server_pending) = {
            let st = self.state_mut(i);
            st.device_grant = Some(now);
            let device_wait = match st.pre.decision {
                crate::coordinator::dispatch::Decision::Both { device_wait } => device_wait,
                _ => 0.0,
            };
            let dev_start_rel = device_wait.max((now - req.arrival).max(0.0));
            let dev_first_abs = req.arrival + dev_start_rel + st.pre.dev_prefill_dur;
            (
                dev_first_abs,
                st.needs_server && st.server_admit.is_none() && !srv_cancelled,
            )
        };
        self.device_delays.push((now - req.arrival).max(0.0));
        if server_pending && dev_first_abs.is_finite() {
            self.push(dev_first_abs, EvKind::DeviceFirstProbe(i));
        }
    }

    /// Resolve the request once every resource it needs is granted or
    /// cancelled.
    fn try_resolve(&mut self, i: usize, now: f64) {
        let srv_cancelled = self.server_cancelled[i];
        let dev_cancelled = self.device_cancelled[i];
        let ready = {
            let st = self.state(i);
            !st.resolved
                && (!st.needs_server || st.server_admit.is_some() || srv_cancelled)
                && (!st.needs_device || st.device_grant.is_some() || dev_cancelled)
        };
        if !ready {
            return;
        }
        let req = self.req(i);
        let (times, pre, mut rng, device_grant, server_was_admitted) = {
            let st = self.state_mut(i);
            st.resolved = true;
            let times = ResourceTimes {
                server_admit: if srv_cancelled { None } else { st.server_admit },
                device_grant: if dev_cancelled {
                    f64::INFINITY
                } else {
                    st.device_grant.unwrap_or(f64::INFINITY)
                },
            };
            (
                times,
                st.pre,
                st.rng.clone(),
                st.device_grant,
                st.server_admit.is_some() && !srv_cancelled,
            )
        };
        let resolved = resolve_request(
            req,
            &pre,
            self.policy,
            &self.scenario.server,
            &self.scenario.device,
            &self.planner,
            &self.scenario.cfg,
            times,
            &mut rng,
        );

        // Completion horizon: last delivered token of this stream.
        let done = req.arrival + resolved.record.ttft + resolved.record.tbts.iter().sum::<f64>();
        if done.is_finite() {
            self.horizon = self.horizon.max(done);
        }

        // Server slot accounting + release.
        if server_was_admitted {
            let admit = times.server_admit.expect("admitted");
            let release = resolved.server_release.unwrap_or(admit).max(admit);
            self.server_busy += release - admit;
            if self.fleet.server_slots.is_some() {
                self.push(release.max(now), EvKind::ServerRelease);
            }
        }
        // (An entry cancelled while still queued holds no slot; the
        // lazily-skipped queue entry frees nothing.)

        // Device accounting + release.
        if let (Some(grant), false) = (device_grant, dev_cancelled) {
            let until = resolved.device_busy_until.unwrap_or(grant).max(grant);
            self.device_busy += until - grant;
            if self.fleet.device_queueing {
                self.push(until.max(now), EvKind::DeviceRelease);
            }
        }

        self.records[i] = Some(resolved.record);
    }
}

/// Run a trace through the fleet loop. Requests must arrive in
/// nondecreasing time order (the trace generators guarantee this); ties
/// are broken in trace order.
pub fn run_fleet(
    scenario: &Scenario,
    trace: &Trace,
    policy: &Policy,
    fleet: &FleetConfig,
) -> FleetOutcome {
    let n = trace.len();
    // A zero-slot pool could never admit anyone; normalize once so the
    // pool and the reported LoadReport.server_slots always agree.
    let fleet = FleetConfig {
        server_slots: fleet.server_slots.map(|s| s.max(1)),
        device_queueing: fleet.device_queueing,
    };
    let sim = FleetSim {
        scenario,
        trace,
        policy,
        planner: MigrationPlanner::new(scenario.cfg.migration, scenario.costs),
        fleet,
        heap: BinaryHeap::new(),
        seq: 0,
        states: (0..n).map(|_| None).collect(),
        server_cancelled: vec![false; n],
        device_cancelled: vec![false; n],
        server_pool: Pool::new(fleet.server_slots),
        device_pool: Pool::new(if fleet.device_queueing { Some(1) } else { None }),
        records: (0..n).map(|_| None).collect(),
        server_delays: Vec::new(),
        device_delays: Vec::new(),
        server_busy: 0.0,
        device_busy: 0.0,
        horizon: 0.0,
    };
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyKind;
    use crate::cost::unified::Constraint;
    use crate::profiles::{DeviceProfile, ServerProfile};
    use crate::sim::engine::SimConfig;
    use crate::trace::generator::{Arrival, WorkloadSpec};

    fn scenario(seed: u64) -> Scenario {
        Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    fn trace_at_gap(n: usize, gap: f64, seed: u64) -> Trace {
        WorkloadSpec {
            arrival: Arrival::Fixed { gap },
            ..WorkloadSpec::alpaca(n)
        }
        .generate(seed)
    }

    #[test]
    fn unlimited_fleet_is_byte_identical_to_replay() {
        let sc = scenario(21);
        let trace = WorkloadSpec::alpaca(300).generate(5);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        let legacy = sc.run(&trace, &policy);
        let fleet = run_fleet(&sc, &trace, &policy, &FleetConfig::replay(false));
        assert_eq!(legacy, fleet.records);
    }

    #[test]
    fn generous_capacity_matches_replay_closely() {
        // With capacity far above offered load the admission queue never
        // forms and the bounded fleet reproduces the replay results.
        let sc = scenario(22);
        let trace = trace_at_gap(200, 60.0, 6);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let replay = sc.run_report(&trace, &policy);
        let fleet = sc.run_fleet_report(
            &trace,
            &policy,
            &FleetConfig {
                server_slots: Some(64),
                device_queueing: false,
            },
        );
        let dm = (fleet.qoe.ttft.mean - replay.ttft.mean).abs() / replay.ttft.mean;
        let dp = (fleet.qoe.ttft.p99 - replay.ttft.p99).abs() / replay.ttft.p99;
        assert!(dm < 0.02, "mean TTFT drift {dm:.4}");
        assert!(dp < 0.02, "p99 TTFT drift {dp:.4}");
        assert!(fleet.load.server_queue_delay.max < 1e-9);
    }

    // (Queue-delay monotonicity in load is asserted once, end-to-end, in
    // tests/integration.rs::fleet_queue_delay_monotone_in_load.)

    #[test]
    fn server_utilization_bounded_by_one() {
        let sc = scenario(24);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let trace = trace_at_gap(120, 0.5, 8);
        let out = sc.run_fleet_report(&trace, &policy, &FleetConfig::bounded(2));
        let util = out.load.server_utilization().unwrap();
        assert!(util > 0.5, "overloaded pool should be busy, util={util:.3}");
        assert!(util <= 1.0 + 1e-9, "util {util:.3} > 1");
        assert!(out.load.mean_server_concurrency() <= 2.0 + 1e-9);
    }

    #[test]
    fn device_fallback_bounds_overloaded_server() {
        // A slow server (DeepSeek: ~1.25 s TTFT + ~30 tok/s decode) with
        // one admission slot at ~1.3× overload queues without bound under
        // ServerOnly. Racing both endpoints lets the single-flight device
        // absorb the traffic (short outputs keep its service time under
        // the arrival gap), so the first token stays bounded AND winning
        // devices cancel the queued server entries, shedding server load.
        let sc = Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed: 25,
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed { gap: 1.4 },
            prompt: crate::trace::generator::LengthModel::new(20.0, 0.5, 4, 128),
            output: crate::trace::generator::LengthModel::new(16.0, 0.3, 4, 32),
            ..WorkloadSpec::alpaca(120)
        };
        let trace = spec.generate(9);
        let fleet_cfg = FleetConfig {
            server_slots: Some(1),
            device_queueing: true,
        };
        let server_only = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let race = Policy::simple(PolicyKind::StochS, 1.0, false);
        let rs = sc.run_fleet_report(&trace, &server_only, &fleet_cfg);
        let rr = sc.run_fleet_report(&trace, &race, &fleet_cfg);
        assert!(
            rs.qoe.ttft.p99 > 3.0 * rr.qoe.ttft.p99,
            "device fallback should bound p99: ServerOnly {:.2}s vs race {:.2}s",
            rs.qoe.ttft.p99,
            rr.qoe.ttft.p99
        );
        assert!(
            rr.qoe.ttft.p99 < 10.0,
            "raced p99 should stay bounded, got {:.2}s",
            rr.qoe.ttft.p99
        );
    }

    #[test]
    fn fleet_run_is_deterministic() {
        let sc = scenario(26);
        let trace = trace_at_gap(100, 1.0, 10);
        let policy = Policy::simple(PolicyKind::StochS, 0.8, false);
        let cfg = FleetConfig::bounded(2);
        let a = run_fleet(&sc, &trace, &policy, &cfg);
        let b = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(a.records, b.records);
    }
}
