//! Discrete-event fleet simulator: N concurrent requests contending for a
//! sharded server fleet and a single-flight device.
//!
//! The paper evaluates per-request (each request sees the profiled latency
//! distributions independently). At fleet scale the interesting effects
//! are *contention* effects: servers with finite admission capacity build
//! queues as load rises, and the on-device model can only run one
//! inference at a time. This module adds exactly that, as an event loop
//! (over a pluggable [`EventQueue`](crate::sim::event_queue::EventQueue)
//! backend — timing wheel by default, binary heap as the reference) over:
//!
//! * **Arrival** events — fork the request's RNG, draw its dispatch
//!   decision through the unchanged `coordinator::policy`, pre-draw its
//!   latency samples, pick a server shard through the configured
//!   [`Balancer`], and enqueue it on the resources it needs;
//! * **grant** transitions — per-shard FIFO pools with `server_slots`
//!   concurrent admissions each, and a FIFO single-flight device pool;
//! * **first-token probes** — when one endpoint produces its first token
//!   while the request is still *queued* on the other endpoint, the
//!   queued entry is cancelled (the §4.2 wait-time strategy extended
//!   across the fleet: nobody waits on a resource after the race is won);
//! * **release** events — slots free at stream end, handoff, or loser
//!   cancellation, admitting the next queued request on that shard.
//!
//! # Shards and balancers
//!
//! The server side is a sharded fleet: `K =
//! FleetConfig::shards` replicas, each with its own bounded slot pool,
//! FIFO queue, and optional extra RTT (heterogeneous placement), fronted
//! by a pluggable [`Balancer`] ([`BalancerKind`]: round-robin, JSQ,
//! power-of-two-choices, least-work). Balancers see only per-shard
//! occupancy snapshots and draw randomness from a dedicated fleet-level
//! stream, so shard choice never perturbs per-request latency draws.
//!
//! # Autoscaling
//!
//! K can react to load during a run: an optional
//! [`AutoscaleConfig`] attaches an [`crate::sim::autoscaler::Autoscaler`]
//! that is evaluated on periodic `AutoscaleEval` events. Scale-out
//! provisions a **cold** shard — its admission pool is frozen until a
//! load-time delay from the configured
//! [`crate::sim::autoscaler::ColdStartSpec`] elapses (a `ShardWarm`
//! event) — and scale-in **drains** a warm victim: the balancer stops
//! routing to it, existing admissions and queued entries finish, then
//! the shard retires. The shard-count timeline, scale events,
//! cold-start seconds, and provisioned shard-seconds surface in
//! [`LoadReport`]. With [`crate::sim::autoscaler::AutoscalerKind::None`]
//! (or no config at all) no evaluation events are scheduled and the run
//! is byte-identical to the static PR-2 fleet.
//!
//! # Migration-aware shard targeting
//!
//! With [`MigrationTargeting::ShardTargeted`], a §4.3 migration that
//! moves generation *onto* the server no longer re-prefills on an
//! abstract base endpoint: the resolve step asks the balancer layer for
//! a target shard ([`crate::sim::balancer::pick_reprefill_target`] —
//! least-work-with-estimate over admitting shards), estimates `t_m`
//! against that shard's endpoint plus its predicted queue delay, and
//! books the migrated stream into the shard's slot pool (a real slot
//! when one is free, batch-join over-commit otherwise) until the stream
//! ends (`MigrationRelease`). When no shard admits, the re-prefill
//! falls back to the base endpoint with the source shard's RTT offset
//! inherited. The default, [`MigrationTargeting::BaseEndpoint`], keeps
//! the PR-3 single-target behavior (byte-for-byte up to the dying-shard
//! RTT fix noted on the variant).
//!
//! # Batching within a shard
//!
//! Each shard serves its admitted streams under a
//! [`crate::sim::batching::BatchingMode`]. The default,
//! `SlotLegacy`, is the historical bounded slot pool (one slot per
//! stream, held for the stream's whole lifetime) and is byte-identical
//! to the pre-batching fleet. `Continuous` replaces the slot count with
//! vLLM/Orca-style continuous batching: prefill admission is gated by a
//! prompt-token budget replenished on periodic `BatchTick` events, and
//! admitted decode streams share the shard's batch — their sampled
//! inter-token gaps are scaled by a pluggable
//! [`crate::sim::batching::BatchLatencyCurve`] evaluated at the batch
//! size the stream joined. A §4.3 migrated-in stream always joins the
//! running batch (its handoff time is committed), which continuous
//! batching makes literal. See `docs/fleet.md` for the model and its
//! join-time-pricing approximation.
//!
//! # Paged KV memory (admission, preemption, prefix caching)
//!
//! `PagedKv` replaces the abstract token budget with the real vLLM
//! constraint: each shard owns a fixed pool of KV blocks
//! ([`crate::sim::kv::KvGate`]). Prefill admission blocks when free
//! pages run out, oversized prompts accrue chunk budget across ticks
//! (Sarathi-style), decode growth allocates a page every
//! `block_tokens` emitted tokens, and when growth pushes the ledger
//! past the pool the shard preempts its lowest-priority running stream
//! — the evicted stream stalls for a deterministic re-prefill delay
//! (its record's inter-token gap stretches; no tokens are lost or
//! duplicated) and re-grows from zero pages. A per-shard prefix index
//! over session prompt lengths lets repeat prompts skip the cached
//! fraction of prefill; a [`ShardOutage`] in paged mode loses in-flight
//! KV, forcing mid-decode re-prefill at a migration target (the forced
//! variant of the paper's §4.3 Eq. 5 buffer sizing). All of it is
//! deterministic and RNG-free, so `SlotLegacy` and `Continuous` runs
//! are byte-identical to a build without the subsystem.
//!
//! # Failure injection
//!
//! Per-shard degradation ([`ShardFault`]: an extra TTFT spike mixture
//! applied to requests balanced onto that shard, drawn from a dedicated
//! fault stream) and scheduled mid-run outages ([`ShardOutage`]: at a
//! given time since the first arrival, the shard is forced into
//! Draining — queued streams re-route to surviving shards, in-flight
//! streams finish under connection-draining semantics, then the shard
//! retires). An outage on an already-draining or retired shard is a
//! no-op, so an outage racing autoscaler scale-in can never
//! double-retire a shard.
//!
//! The per-request trajectory itself (race, cancellation, migration,
//! delivery smoothing, cost metering) is [`crate::sim::engine`]'s
//! `resolve_request` — one code path shared with the legacy replay,
//! which is the degenerate configuration [`FleetConfig::replay`] (one
//! shard, unlimited slots). With that configuration the fleet loop is
//! byte-identical to the historical per-request engine: per-request RNG
//! streams are forked in trace order and all latency samples are
//! pre-drawn at arrival, so resolution timing cannot perturb them.
//!
//! Determinism: the event queue orders events by `(time, sequence)` with
//! `f64::total_cmp`, so runs are bit-reproducible from `SimConfig.seed` —
//! and both queue backends ([`EventQueueKind::Wheel`] and
//! [`EventQueueKind::Heap`], selected by `FleetConfig::event_queue`)
//! realize the *same* total order, so runs are byte-identical across
//! backends too (see `docs/fleet.md` § event queue & determinism
//! contract).

use crate::coordinator::migration::MigrationPlanner;
use crate::coordinator::policy::Policy;
use crate::cost::unified::Constraint;
use crate::endpoint::{EndpointKind, ServerEndpoint};
use crate::metrics::{
    BatchSample, LoadReport, RequestRecord, ScaleEvent, ScaleEventKind, ShardCountSample,
    ShardLoad,
};
use crate::sim::autoscaler::{
    AutoscaleConfig, Autoscaler, FleetView, LifecyclePhase, ScaleAction, ShardStatus,
};
use crate::sim::balancer::{pick_reprefill_target, Balancer, BalancerKind, ShardIndex, ShardView};
use crate::sim::batching::{BatchingMode, ContinuousBatchConfig, PricingMode};
use crate::sim::delivery;
use crate::sim::engine::{
    pre_draw, resolve_request, BatchCtx, MigrationServer, PreDrawn, ResourceTimes, Scenario,
};
use crate::sim::event_queue::{EventQueue, EventQueueKind};
use crate::sim::kv::{KvConfig, KvGate};
use crate::stats::describe::Summary;
use crate::trace::Trace;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::VecDeque;

/// How a §4.3 migration that moves generation onto the server picks its
/// re-prefill target.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MigrationTargeting {
    /// The historical single-target behavior: re-prefill estimates and
    /// samples come from the source shard's endpoint (or the base
    /// endpoint for device-only streams), and the migrated stream
    /// occupies no shard. Byte-identical to the PR-3 fleet except for
    /// the dying-shard fix: a stream resolving on a draining/retired
    /// shard now keeps that shard's RTT offset instead of silently
    /// dropping it (see the engine regression test) — identical
    /// whenever shard RTTs are zero or no shard is draining at resolve
    /// time.
    #[default]
    BaseEndpoint,
    /// Least-work-with-estimate shard targeting: the resolve step picks
    /// an admitting shard via
    /// [`crate::sim::balancer::pick_reprefill_target`], folds the
    /// shard's RTT and predicted queue delay into the `t_m` estimate,
    /// and books the migrated stream into that shard's slot pool until
    /// the stream ends. Falls back to the base endpoint (source RTT
    /// inherited) when no shard admits.
    ShardTargeted,
}

impl MigrationTargeting {
    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            MigrationTargeting::BaseEndpoint => "base-endpoint",
            MigrationTargeting::ShardTargeted => "shard-targeted",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<MigrationTargeting> {
        Some(match s.to_ascii_lowercase().as_str() {
            "base" | "base-endpoint" | "legacy" => MigrationTargeting::BaseEndpoint,
            "shard" | "shard-targeted" | "targeted" => MigrationTargeting::ShardTargeted,
            _ => return None,
        })
    }
}

impl std::fmt::Display for MigrationTargeting {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-shard degradation: an *additional* TTFT spike mixture applied to
/// requests balanced onto the shard, on top of the base server profile
/// (the §2.3 partial-backend-failure scenario: one replica degrades, the
/// fleet does not). Spike draws come from a dedicated fault stream, so a
/// fleet with no faults configured is byte-identical to one without the
/// feature.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardFault {
    /// Probability an arrival on this shard hits the degradation spike.
    pub spike_prob: f64,
    /// Median multiplier applied to the pre-drawn prefill sample during
    /// a spike (log-normal with σ = 0.5, like the profile's own mixture).
    pub spike_scale: f64,
}

/// A scheduled mid-run shard outage: at `at` seconds after the first
/// arrival, the shard is forced into Draining — queued streams re-route
/// to surviving shards, in-flight streams finish (connection draining),
/// then the shard retires. A no-op if the shard is already draining,
/// retired, or not (yet) provisioned.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardOutage {
    /// Seconds after the first arrival at which the shard fails.
    pub at: f64,
    /// Index of the shard to kill.
    pub shard: usize,
}

/// Server-side resource spec: fleet topology plus the within-shard
/// admission regime. One of the three grouped views of [`FleetConfig`]
/// (`with_server` / `with_control` / `with_faults`); the historical
/// flat builders delegate through these.
#[derive(Clone, Debug)]
pub struct ServerSpec {
    /// Number of server shards (replicas), K ≥ 1.
    pub shards: usize,
    /// Concurrent admissions per shard (`None` = unlimited).
    pub server_slots: Option<usize>,
    /// Optional per-shard extra RTT offsets (seconds).
    pub shard_rtts: Vec<f64>,
    /// Slot / continuous-batching / paged-KV admission regime.
    pub batching: BatchingMode,
    /// Join-time vs iteration-level decode pricing for the gated modes.
    pub pricing: PricingMode,
}

impl Default for ServerSpec {
    fn default() -> Self {
        ServerSpec {
            shards: 1,
            server_slots: None,
            shard_rtts: Vec::new(),
            batching: BatchingMode::SlotLegacy,
            pricing: PricingMode::JoinTime,
        }
    }
}

/// Control-plane spec: how work is routed and capacity managed — the
/// balancer, optional autoscaler, §4.3 migration targeting, and the
/// event-queue backend.
#[derive(Clone, Debug)]
pub struct ControlSpec {
    pub balancer: BalancerKind,
    pub autoscale: Option<AutoscaleConfig>,
    pub migration_targeting: MigrationTargeting,
    pub event_queue: EventQueueKind,
    /// Whether §4.3 server-bound re-prefill tails under
    /// [`MigrationTargeting::BaseEndpoint`] are priced at the source
    /// shard's batch in the gated modes (`true`, the fixed default) or
    /// left unpriced at slowdown 1.0 (the documented PR-5 legacy
    /// quirk, kept reachable for regression pinning).
    pub price_base_tails: bool,
}

impl Default for ControlSpec {
    fn default() -> Self {
        ControlSpec {
            balancer: BalancerKind::RoundRobin,
            autoscale: None,
            migration_targeting: MigrationTargeting::BaseEndpoint,
            event_queue: EventQueueKind::default(),
            price_base_tails: true,
        }
    }
}

/// Failure-injection plan: per-shard degradation plus scheduled mid-run
/// outages. The default (empty) plan injects nothing.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Per-shard degradation overrides, indexed by shard.
    pub shard_faults: Vec<Option<ShardFault>>,
    /// Scheduled outages (times relative to the first arrival).
    pub outages: Vec<ShardOutage>,
}

impl FaultPlan {
    /// Degrade shard `shard` with an extra TTFT spike mixture.
    pub fn fault(mut self, shard: usize, fault: ShardFault) -> FaultPlan {
        if self.shard_faults.len() <= shard {
            self.shard_faults.resize(shard + 1, None);
        }
        self.shard_faults[shard] = Some(fault);
        self
    }

    /// Schedule an outage `at` seconds after the first arrival.
    pub fn outage(mut self, at: f64, shard: usize) -> FaultPlan {
        self.outages.push(ShardOutage { at, shard });
        self
    }
}

/// Fleet-level resource configuration: the server fleet topology (shard
/// count, per-shard admission slots, optional per-shard RTT offsets), the
/// balancer fronting it, device single-flight modeling, migration
/// targeting, and failure injection.
///
/// The surface is organized into three grouped sub-configs —
/// [`ServerSpec`] (topology + admission regime), [`ControlSpec`]
/// (balancer / autoscaler / migration / event queue), and [`FaultPlan`]
/// (degradation + outages) — read back with `server_spec()` /
/// `control_spec()` / `fault_plan()` and replaced wholesale with
/// `with_server` / `with_control` / `with_faults`. The flat per-field
/// builders below are kept as thin shims that delegate through the
/// grouped API, so historical call sites compile (and run)
/// byte-identically.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Concurrent admissions *per shard*; `None` = unlimited (the paper's
    /// independent replay, where server TTFT already folds queueing in
    /// statistically).
    pub server_slots: Option<usize>,
    /// Model the single-flight device across requests.
    pub device_queueing: bool,
    /// Number of server shards (replicas), K ≥ 1. K = 1 is the PR-1
    /// single-pool fleet; balancers are bypassed entirely at K = 1.
    pub shards: usize,
    /// How arriving server-bound requests spread across shards.
    pub balancer: BalancerKind,
    /// Optional per-shard extra RTT offsets (seconds), indexed by shard
    /// and added to that shard's TTFT (heterogeneous replica placement).
    /// Shorter than `shards` is padded with 0.0; empty = homogeneous.
    pub shard_rtts: Vec<f64>,
    /// Optional shard autoscaling. `None` — or a config whose kind is
    /// `AutoscalerKind::None` — keeps the static topology and is
    /// byte-identical to the PR-2 fleet (no evaluation events are
    /// scheduled at all).
    pub autoscale: Option<AutoscaleConfig>,
    /// How server-bound §4.3 re-prefills pick their target. The default
    /// ([`MigrationTargeting::BaseEndpoint`]) is the PR-3 behavior.
    pub migration_targeting: MigrationTargeting,
    /// Per-shard degradation overrides, indexed by shard (`None` =
    /// healthy). Shorter than `shards` is padded with `None`; shards
    /// provisioned later by the autoscaler are always healthy.
    pub shard_faults: Vec<Option<ShardFault>>,
    /// Scheduled mid-run shard outages (times relative to the first
    /// arrival). Empty = no failure injection, byte-identical to PR-3.
    pub outages: Vec<ShardOutage>,
    /// How each shard admits and serves concurrent streams. The default
    /// ([`BatchingMode::SlotLegacy`]) is the historical slot pool,
    /// byte-identical to the pre-batching fleet; `Continuous` switches
    /// to token-budget prefill admission and batch-size-dependent
    /// decode (ignoring `server_slots` — the batch, not a slot count,
    /// bounds concurrency).
    pub batching: BatchingMode,
    /// Which event-queue backend orders the loop. Both backends realize
    /// the exact `(time, seq)` total order, so runs are byte-identical
    /// across them; the default timing wheel is the fast path, the
    /// binary heap the reference implementation the parity tests pin
    /// against.
    pub event_queue: EventQueueKind,
    /// Decode pricing for the gated batching modes: freeze each
    /// stream's slowdown at join time (the historical default) or
    /// reprice pending gaps at every batch-size change
    /// ([`PricingMode::IterationLevel`]). Inert under `SlotLegacy`,
    /// `Flat` curves, and batches that never exceed one stream — the
    /// repricing parity matrix pins byte-identical runs there.
    pub pricing: PricingMode,
    /// Price base-endpoint §4.3 server-bound re-prefill tails at the
    /// source shard's live batch in the gated modes (default `true`).
    /// `false` restores the PR-5 legacy quirk (tails decode at
    /// slowdown 1.0 regardless of the batch they join).
    pub price_base_tails: bool,
}

impl FleetConfig {
    /// The legacy per-request replay configuration (one shard, unlimited
    /// admission).
    pub fn replay(device_queueing: bool) -> FleetConfig {
        FleetConfig {
            server_slots: None,
            device_queueing,
            shards: 1,
            balancer: BalancerKind::RoundRobin,
            shard_rtts: Vec::new(),
            autoscale: None,
            migration_targeting: MigrationTargeting::BaseEndpoint,
            shard_faults: Vec::new(),
            outages: Vec::new(),
            batching: BatchingMode::SlotLegacy,
            event_queue: EventQueueKind::default(),
            pricing: PricingMode::JoinTime,
            price_base_tails: true,
        }
    }

    /// A bounded single-shard server with single-flight device contention
    /// (the PR-1 fleet shape).
    pub fn bounded(server_slots: usize) -> FleetConfig {
        FleetConfig {
            server_slots: Some(server_slots.max(1)),
            ..FleetConfig::replay(true)
        }
    }

    /// A K-shard fleet with `server_slots` admissions per shard.
    pub fn sharded(shards: usize, server_slots: usize, balancer: BalancerKind) -> FleetConfig {
        FleetConfig {
            server_slots: Some(server_slots.max(1)),
            shards: shards.max(1),
            balancer,
            ..FleetConfig::replay(true)
        }
    }

    // --- grouped sub-config surface ---------------------------------

    /// The server-side grouped view: topology + admission regime.
    pub fn server_spec(&self) -> ServerSpec {
        ServerSpec {
            shards: self.shards,
            server_slots: self.server_slots,
            shard_rtts: self.shard_rtts.clone(),
            batching: self.batching,
            pricing: self.pricing,
        }
    }

    /// Replace the server-side spec wholesale.
    pub fn with_server(mut self, spec: ServerSpec) -> FleetConfig {
        self.shards = spec.shards;
        self.server_slots = spec.server_slots;
        self.shard_rtts = spec.shard_rtts;
        self.batching = spec.batching;
        self.pricing = spec.pricing;
        self
    }

    /// The control-plane grouped view: balancer, autoscaler, migration
    /// targeting, event queue.
    pub fn control_spec(&self) -> ControlSpec {
        ControlSpec {
            balancer: self.balancer,
            autoscale: self.autoscale,
            migration_targeting: self.migration_targeting,
            event_queue: self.event_queue,
            price_base_tails: self.price_base_tails,
        }
    }

    /// Replace the control-plane spec wholesale.
    pub fn with_control(mut self, spec: ControlSpec) -> FleetConfig {
        self.balancer = spec.balancer;
        self.autoscale = spec.autoscale;
        self.migration_targeting = spec.migration_targeting;
        self.event_queue = spec.event_queue;
        self.price_base_tails = spec.price_base_tails;
        self
    }

    /// The failure-injection grouped view: faults + outages.
    pub fn fault_plan(&self) -> FaultPlan {
        FaultPlan {
            shard_faults: self.shard_faults.clone(),
            outages: self.outages.clone(),
        }
    }

    /// Replace the failure-injection plan wholesale.
    pub fn with_faults(mut self, plan: FaultPlan) -> FleetConfig {
        self.shard_faults = plan.shard_faults;
        self.outages = plan.outages;
        self
    }

    // --- flat builders (thin shims over the grouped surface) ---------

    /// Same topology with heterogeneous per-shard RTT offsets.
    pub fn with_shard_rtts(self, rtts: Vec<f64>) -> FleetConfig {
        let spec = ServerSpec {
            shard_rtts: rtts,
            ..self.server_spec()
        };
        self.with_server(spec)
    }

    /// Attach a shard-autoscaling policy; `shards` becomes the initial
    /// (warm) replica count.
    pub fn with_autoscale(self, autoscale: AutoscaleConfig) -> FleetConfig {
        let spec = ControlSpec {
            autoscale: Some(autoscale),
            ..self.control_spec()
        };
        self.with_control(spec)
    }

    /// Select how §4.3 server-bound re-prefills are targeted.
    pub fn with_migration_targeting(self, targeting: MigrationTargeting) -> FleetConfig {
        let spec = ControlSpec {
            migration_targeting: targeting,
            ..self.control_spec()
        };
        self.with_control(spec)
    }

    /// Degrade one shard with an extra TTFT spike mixture. Faults on
    /// indices at or beyond the static `shards` count are dropped at run
    /// time (autoscaler-provisioned shards are always healthy).
    pub fn with_shard_fault(self, shard: usize, fault: ShardFault) -> FleetConfig {
        let plan = self.fault_plan().fault(shard, fault);
        self.with_faults(plan)
    }

    /// Schedule a mid-run shard outage (`at` seconds after the first
    /// arrival).
    pub fn with_outage(self, at: f64, shard: usize) -> FleetConfig {
        let plan = self.fault_plan().outage(at, shard);
        self.with_faults(plan)
    }

    /// Select the within-shard batching model. `Continuous` replaces
    /// the per-shard slot cap with token-budget prefill admission and a
    /// shared decode batch; `server_slots` is then ignored. `PagedKv`
    /// gates admission on KV pages instead (see [`Self::with_kv`]).
    pub fn with_batching(self, batching: BatchingMode) -> FleetConfig {
        let spec = ServerSpec {
            batching,
            ..self.server_spec()
        };
        self.with_server(spec)
    }

    /// Switch the fleet to the paged-KV memory model: per-shard KV
    /// block pools, Sarathi chunked prefill admission, decode page
    /// growth with memory-pressure preemption, prefix caching, and
    /// KV-aware hard failover. Shorthand for
    /// `with_batching(BatchingMode::PagedKv(cfg))`.
    pub fn with_kv(self, cfg: KvConfig) -> FleetConfig {
        self.with_batching(BatchingMode::PagedKv(cfg))
    }

    /// Select the event-queue backend. The timing wheel (default) and
    /// the binary heap produce byte-identical runs; the heap exists as
    /// the reference the parity suite compares against.
    pub fn with_event_queue(self, kind: EventQueueKind) -> FleetConfig {
        let spec = ControlSpec {
            event_queue: kind,
            ..self.control_spec()
        };
        self.with_control(spec)
    }

    /// Select join-time vs iteration-level decode pricing for the gated
    /// batching modes (a no-op under `SlotLegacy`).
    pub fn with_pricing(self, pricing: PricingMode) -> FleetConfig {
        let spec = ServerSpec {
            pricing,
            ..self.server_spec()
        };
        self.with_server(spec)
    }

    /// Toggle batch pricing of base-endpoint §4.3 re-prefill tails
    /// (`false` restores the PR-5 legacy unpriced path).
    pub fn with_base_tail_pricing(self, price_base_tails: bool) -> FleetConfig {
        let spec = ControlSpec {
            price_base_tails,
            ..self.control_spec()
        };
        self.with_control(spec)
    }

    /// Convenience: a K-shard continuous-batching fleet.
    pub fn continuous(
        shards: usize,
        cfg: ContinuousBatchConfig,
        balancer: BalancerKind,
    ) -> FleetConfig {
        FleetConfig {
            shards: shards.max(1),
            balancer,
            batching: BatchingMode::Continuous(cfg),
            ..FleetConfig::replay(true)
        }
    }
}

/// Result of a fleet run: per-request records (trace order) plus load
/// metrics. Zone-partitioned runs (`sim/zones.rs`) merge Z of these —
/// records re-sorted by the stable `(arrival, zone, seq)` key, load
/// reports folded via [`LoadReport::merge_zones`] — into one outcome
/// that is byte-identical at Z=1 to a plain [`run_fleet`] call.
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    pub records: Vec<RequestRecord>,
    pub load: LoadReport,
}

// ---------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------
//
// The queue itself — `(time, seq)` total ordering, wheel and heap
// backends — lives in `crate::sim::event_queue`; the fleet only defines
// its event payload.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EvKind {
    Arrival(usize),
    /// Request `.0`'s server stream ended: its shard's admission slot
    /// frees (admit the next queued request) and its work estimate
    /// retires from the shard.
    ServerRelease(usize),
    /// The device frees; grant it to the next queued request.
    DeviceRelease,
    /// The server produced its first token while the request was still
    /// queued for the device: cancel the device entry and resolve.
    ServerFirstProbe(usize),
    /// The device produced its first token while the request was still
    /// queued for server admission: cancel the server entry and resolve.
    DeviceFirstProbe(usize),
    /// Periodic autoscaler evaluation tick (only scheduled when a
    /// scaling policy is attached).
    AutoscaleEval,
    /// Cold shard `.0` finished loading its model: unfreeze its pool and
    /// admit anything already queued on it.
    ShardWarm(usize),
    /// Injected failure: force shard `.0` into Draining, re-route its
    /// queued streams, and let in-flight streams finish (connection
    /// draining). No-op on an already draining/retired/unprovisioned
    /// shard.
    Outage(usize),
    /// Request `.0`'s migrated stream (re-prefilled onto a target shard
    /// under [`MigrationTargeting::ShardTargeted`]) ended: release its
    /// occupancy on that shard and retire its work estimate.
    MigrationRelease(usize),
    /// Continuous-batching scheduling tick: replenish every live
    /// shard's prompt-token admission budget and admit queued prefills
    /// FIFO while it lasts. Only scheduled under
    /// [`BatchingMode::Continuous`]; reschedules itself until every
    /// request has resolved.
    BatchTick,
}

// ---------------------------------------------------------------------
// Resource pools
// ---------------------------------------------------------------------

/// Continuous-batching admission gate: prefill admission consumes a
/// prompt-token budget replenished every scheduling tick instead of a
/// slot. A prompt longer than the whole per-tick budget is admitted
/// when the tick's budget is untouched (consuming all of it), so
/// oversized prompts cannot starve behind the gate.
#[derive(Debug)]
struct BatchGate {
    /// Prompt tokens admissible per scheduling tick.
    budget_per_tick: u64,
    /// Remaining budget in the current tick.
    budget_left: u64,
    /// Optional cap on concurrently decoding streams.
    max_batch: Option<usize>,
    /// Prompt tokens actually admitted (token-budget utilization
    /// numerator).
    admitted_tokens: u64,
    /// Budget made available so far: the initial allotment plus one
    /// `budget_per_tick` per tick (the utilization denominator).
    capacity_tokens: u64,
}

impl BatchGate {
    fn new(cfg: &ContinuousBatchConfig) -> BatchGate {
        let per = cfg.prefill_tokens_per_tick.max(1) as u64;
        BatchGate {
            budget_per_tick: per,
            budget_left: per,
            max_batch: cfg.max_batch,
            admitted_tokens: 0,
            capacity_tokens: per,
        }
    }

    fn admits(&self, in_use: usize, tokens: u32) -> bool {
        if let Some(mb) = self.max_batch {
            if in_use >= mb {
                return false;
            }
        }
        let t = tokens as u64;
        let fresh = self.budget_left == self.budget_per_tick;
        t <= self.budget_left || (fresh && t > self.budget_per_tick)
    }

    fn consume(&mut self, tokens: u32) {
        self.admitted_tokens += tokens as u64;
        self.budget_left = self.budget_left.saturating_sub(tokens as u64);
    }

    fn tick(&mut self) {
        self.budget_left = self.budget_per_tick;
        self.capacity_tokens += self.budget_per_tick;
    }
}

/// Admission gate attached to a pool: the continuous-batching token
/// budget or the paged-KV page ledger. `None` on the pool = slot
/// semantics.
#[derive(Debug)]
enum Gate {
    Batch(BatchGate),
    Kv(KvGate),
}

/// Build the gate matching the fleet's (normalized) batching mode.
fn make_gate(batching: &BatchingMode) -> Option<Gate> {
    match batching {
        BatchingMode::SlotLegacy => None,
        BatchingMode::Continuous(c) => Some(Gate::Batch(BatchGate::new(c))),
        BatchingMode::PagedKv(k) => Some(Gate::Kv(KvGate::new(k))),
    }
}

/// FIFO admission pool. Under slot semantics (`gate == None`) it is a
/// (possibly unlimited) concurrency cap; under continuous batching the
/// cap is gone and a [`BatchGate`] token budget gates admission
/// instead. Cancelled entries are skipped lazily at pop time; live-entry
/// and queued-token counters are maintained incrementally (adjusted at
/// cancellation via [`Pool::cancel_queued`]) so the balancer's
/// per-arrival snapshot is O(1) per shard instead of an O(queue) rescan.
#[derive(Debug)]
struct Pool {
    cap: Option<usize>,
    in_use: usize,
    /// Units of `in_use` booked by §4.3 batch-join over-commits
    /// (`acquire_overflow` past the cap, or any migrated-in join under
    /// continuous batching). Tracked separately from real slots so a
    /// spurious second over-commit release can never free a slot a real
    /// holder still occupies, and so occupancy and over-commit surface
    /// separately in [`ShardLoad`].
    over_commit: usize,
    queue: VecDeque<usize>,
    /// Non-cancelled entries currently in `queue`.
    live: usize,
    /// Prompt tokens of the live queued entries — the token-backlog
    /// signal balancers, the autoscaler, and the migration planner read
    /// under continuous batching.
    queued_tokens: u64,
    /// A frozen (cold-shard) pool queues every acquire unconditionally;
    /// nothing admits until the shard's warm-up event unfreezes it.
    /// Static fleets never freeze, so the PR-2 semantics are untouched.
    frozen: bool,
    /// Releases that found nothing to release (a double release).
    /// Previously `saturating_sub` silently absorbed these, masking the
    /// bug as a permanent capacity leak; now they are counted (and
    /// debug-asserted) and surface in `LoadReport::release_underflows`.
    /// Always 0 on a correct event flow.
    underflows: usize,
    /// High-water mark of `in_use`: the peak batch size under
    /// continuous batching, peak occupancy (incl. over-commit) under
    /// slots.
    peak_in_use: usize,
    /// Admission gate: continuous-batching token budget or paged-KV
    /// page ledger (`None` = slot semantics).
    gate: Option<Gate>,
}

impl Pool {
    fn new(cap: Option<usize>) -> Pool {
        Pool {
            cap,
            in_use: 0,
            over_commit: 0,
            queue: VecDeque::new(),
            live: 0,
            queued_tokens: 0,
            frozen: false,
            underflows: 0,
            peak_in_use: 0,
            gate: None,
        }
    }

    /// A cold shard's pool: queues everything until unfrozen.
    fn new_frozen(cap: Option<usize>) -> Pool {
        Pool {
            frozen: true,
            ..Pool::new(cap)
        }
    }

    /// Attach (or not) a continuous-batching gate.
    fn with_gate(self, gate: Option<BatchGate>) -> Pool {
        self.with_gate_kind(gate.map(Gate::Batch))
    }

    /// Attach (or not) an admission gate of either kind.
    fn with_gate_kind(mut self, gate: Option<Gate>) -> Pool {
        self.gate = gate;
        self
    }

    /// The paged-KV gate, if this pool carries one.
    fn kv(&self) -> Option<&KvGate> {
        match &self.gate {
            Some(Gate::Kv(g)) => Some(g),
            _ => None,
        }
    }

    fn kv_mut(&mut self) -> Option<&mut KvGate> {
        match &mut self.gate {
            Some(Gate::Kv(g)) => Some(g),
            _ => None,
        }
    }

    /// Whether an arrival with `tokens` prompt tokens can admit right
    /// now (ignoring the frozen flag, which callers check first).
    fn admits_now(&self, tokens: u32) -> bool {
        match &self.gate {
            Some(Gate::Batch(g)) => g.admits(self.in_use, tokens),
            Some(Gate::Kv(g)) => g.admits(tokens),
            None => match self.cap {
                None => true,
                Some(cap) => self.in_use < cap,
            },
        }
    }

    /// Consume one admission: bump `in_use` (and the token budget or
    /// page ledger under a gate) and track the peak.
    fn admit_now(&mut self, tokens: u32) {
        self.in_use += 1;
        if self.in_use > self.peak_in_use {
            self.peak_in_use = self.in_use;
        }
        match &mut self.gate {
            Some(Gate::Batch(g)) => g.consume(tokens),
            Some(Gate::Kv(g)) => g.consume(tokens),
            None => {}
        }
    }

    /// Checked release of one `in_use` unit: a double release is
    /// recorded (and debug-asserted) instead of being silently clamped
    /// into a permanent capacity leak.
    fn dec_in_use(&mut self) {
        debug_assert!(self.in_use > 0, "pool release with nothing in use");
        if self.in_use == 0 {
            self.underflows += 1;
        } else {
            self.in_use -= 1;
        }
    }

    /// Try to acquire; queues and returns false when full, frozen, or
    /// out of token budget. Unlimited pools admit immediately but still
    /// count `in_use`, so balancers see real in-service load even
    /// without a slot cap.
    ///
    /// Admission is FIFO: under a token gate a live entry may be queued
    /// while budget remains (its prompt didn't fit the tick), and a new
    /// small arrival must queue behind it rather than jump it. Slot
    /// pools never have a live queue alongside spare capacity (releases
    /// transfer), so the guard is gated to batch mode and legacy
    /// behavior is untouched.
    fn acquire(&mut self, i: usize, tokens: u32) -> bool {
        let fifo_blocked = self.gate.is_some() && self.live > 0;
        if !self.frozen && !fifo_blocked && self.admits_now(tokens) {
            self.admit_now(tokens);
            return true;
        }
        self.queue.push_back(i);
        self.live += 1;
        self.queued_tokens += tokens as u64;
        false
    }

    /// Admit the next live queued entry if the pool has spare capacity
    /// (or token budget) and is not frozen — the unit is newly
    /// consumed, unlike the slot-transfer path of [`Pool::release`].
    /// `tokens[j]` is request `j`'s prompt length.
    fn try_admit(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.frozen {
            return None;
        }
        loop {
            let &j = self.queue.front()?;
            if cancelled[j] {
                // Cancelled entries left `live` (and `queued_tokens`)
                // at cancellation time; just drop the dead slot.
                self.queue.pop_front();
                continue;
            }
            if !self.admits_now(tokens[j]) {
                return None;
            }
            self.queue.pop_front();
            self.live = self.live.saturating_sub(1);
            self.queued_tokens = self.queued_tokens.saturating_sub(tokens[j] as u64);
            self.admit_now(tokens[j]);
            return Some(j);
        }
    }

    /// Release one unit; returns the next queued request to admit, if
    /// any. Under slot semantics the unit *transfers* to the next live
    /// queued entry; under a batch gate the departing stream only frees
    /// batch headroom and any admission stays token-gated.
    fn release(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.gate.is_some() {
            self.dec_in_use();
            return self.try_admit(cancelled, tokens);
        }
        while let Some(j) = self.queue.pop_front() {
            if !cancelled[j] {
                self.live = self.live.saturating_sub(1);
                self.queued_tokens = self.queued_tokens.saturating_sub(tokens[j] as u64);
                return Some(j);
            }
        }
        self.dec_in_use();
        None
    }

    /// A queued entry was cancelled (its lazily-skipped queue slot is
    /// now dead): keep the live count and token backlog in sync.
    fn cancel_queued(&mut self, tokens: u32) {
        self.live = self.live.saturating_sub(1);
        self.queued_tokens = self.queued_tokens.saturating_sub(tokens as u64);
    }

    /// Live (non-cancelled) queue length — the balancer's view.
    fn live_queued(&self) -> usize {
        self.live
    }

    /// Prompt tokens queued for admission (live entries only).
    fn queued_prompt_tokens(&self) -> u64 {
        self.queued_tokens
    }

    /// Occupy one unit for a §4.3 migrated-in stream. Under slot
    /// semantics it takes a real slot when capacity is spare and
    /// otherwise joins the running batch over-capacity; under
    /// continuous batching it always joins the batch (the handoff time
    /// was already committed, so the stream cannot queue — neither the
    /// token budget nor `max_batch` applies). Returns whether a real
    /// slot was taken, which decides the matching release path.
    fn acquire_overflow(&mut self) -> bool {
        let real = match (&self.gate, self.cap) {
            (Some(_), _) => false,
            (None, Some(cap)) => self.in_use < cap,
            (None, None) => true,
        };
        if !real {
            self.over_commit += 1;
        }
        self.in_use += 1;
        if self.in_use > self.peak_in_use {
            self.peak_in_use = self.in_use;
        }
        real
    }

    /// Release an over-capacity (batch-join) unit. Real slots may have
    /// freed *underneath* the over-commit in the meantime (their release
    /// saw an empty queue and simply decremented), leaving this unit
    /// load-bearing — so after the decrement, any spare capacity admits
    /// the next live queued entry exactly like a real-slot release would
    /// have. Skipping that admission would strand the queue forever: no
    /// later release event exists on the shard.
    ///
    /// A release with no over-commit outstanding is a double release:
    /// it is refused (counted in `underflows`) instead of decrementing
    /// `in_use`, which would free a slot a real holder still occupies —
    /// the accounting bug this PR's sweep fixed.
    fn release_overflow(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.over_commit == 0 {
            debug_assert!(false, "over-commit release with no over-commit outstanding");
            self.underflows += 1;
            return None;
        }
        self.over_commit -= 1;
        self.dec_in_use();
        self.try_admit(cancelled, tokens)
    }

    /// Remove every live queued entry (outage re-routing); cancelled
    /// entries are dropped on the way. Leaves the queue empty.
    fn drain_queue(&mut self, cancelled: &[bool]) -> Vec<usize> {
        let mut live = Vec::with_capacity(self.live);
        while let Some(j) = self.queue.pop_front() {
            if !cancelled[j] {
                live.push(j);
            }
        }
        self.live = 0;
        self.queued_tokens = 0;
        live
    }

    /// Replenish the token budget at a scheduling tick (no-op for slot
    /// pools). An *idle* tick — budget untouched and nothing queued —
    /// offered no usable capacity and accrues none, so
    /// `token_budget_utilization` measures budget offered while there
    /// was work, not the trace's idle tail.
    fn tick(&mut self) {
        match &mut self.gate {
            Some(Gate::Batch(g)) => {
                let idle = g.budget_left == g.budget_per_tick && self.live == 0;
                if !idle {
                    g.tick();
                }
            }
            Some(Gate::Kv(g)) => {
                // The KV chunk budget accrues (never resets), so only
                // ticks with queued prefill work offer usable capacity;
                // accruing while nothing waits would let a later burst
                // admit unboundedly in one tick.
                if self.live > 0 {
                    g.tick();
                }
            }
            None => {}
        }
    }

    /// (admitted, capacity) prompt-token totals of the gate; zeros for
    /// slot pools.
    fn token_totals(&self) -> (u64, u64) {
        match &self.gate {
            Some(Gate::Batch(g)) => (g.admitted_tokens, g.capacity_tokens),
            Some(Gate::Kv(g)) => g.token_totals(),
            None => (0, 0),
        }
    }
}

// ---------------------------------------------------------------------
// The simulator
// ---------------------------------------------------------------------

/// Per-stream state in dense struct-of-arrays (arena) form, keyed by the
/// request's trace index. The hot loop used to carry this as
/// `Vec<Option<ReqState>>` — one fat option per request, with the RNG
/// cloned back out at resolve time; the arena splits it into columns so
/// each event touches only the cache lines it reads, and the per-request
/// RNG is mutated **in place** (disjoint-field borrows), never cloned.
///
/// Lifecycle: `rng` is pre-forked for every request at run start (trace
/// order — the determinism contract). `pre` is pushed densely at
/// arrival: arrival events are pushed first with sequence numbers
/// `0..n-1` over nondecreasing trace times, so `Arrival(i)` always pops
/// before `Arrival(j)` for `i < j` and `pre.len()` equals the number of
/// requests that have arrived. All other columns are pre-sized to the
/// trace length.
#[derive(Debug)]
struct StreamArena {
    /// Pre-drawn decision + latency samples (valid once arrived).
    pre: Vec<PreDrawn>,
    /// Per-request RNG streams, forked in trace order at run start;
    /// `pre_draw` consumes from the front, the resolve step continues
    /// the same stream in place.
    rng: Vec<Rng>,
    needs_server: Vec<bool>,
    needs_device: Vec<bool>,
    server_admit: Vec<Option<f64>>,
    device_grant: Vec<Option<f64>>,
    resolved: Vec<bool>,
    /// The pre-fault prefill draw, kept when a shard fault degraded
    /// `pre[i].server_sample` — an outage re-route restores it (the
    /// spike belonged to the dead shard, not the stream).
    base_sample: Vec<Option<f64>>,
    /// Multiplier on the stream's server-side decode gaps: the batch
    /// latency curve evaluated at the shard's batch size when the
    /// stream was admitted (1.0 under slot semantics, and until
    /// admission).
    decode_slowdown: Vec<f64>,
}

impl StreamArena {
    fn new(n: usize) -> StreamArena {
        StreamArena {
            pre: Vec::with_capacity(n),
            rng: Vec::new(),
            needs_server: vec![false; n],
            needs_device: vec![false; n],
            server_admit: vec![None; n],
            device_grant: vec![None; n],
            resolved: vec![false; n],
            base_sample: vec![None; n],
            decode_slowdown: vec![1.0; n],
        }
    }
}

/// One server shard: a bounded slot pool plus its load accounting and
/// autoscaling lifecycle (static fleets stay `Warm` forever).
struct ShardState {
    pool: Pool,
    /// Extra RTT (seconds) this shard adds to every first token it serves
    /// (offset relative to the scenario's base server endpoint).
    rtt: f64,
    /// Outstanding estimated service seconds: pre-drawn prefill samples
    /// of requests assigned to this shard that are queued or still hold
    /// a slot (retired at `ServerRelease`, or at resolve for entries
    /// that never held one). The `LeastWork` balancer's signal.
    work: f64,
    busy: f64,
    /// Seconds of §4.3 batch-join occupancy held *above* the shard's
    /// slot capacity (over-commit bookings; real-slot bookings land in
    /// `busy`). Reported separately from `busy` so utilization stays a
    /// within-capacity ratio.
    overcommit_seconds: f64,
    delays: Vec<f64>,
    admitted: usize,
    /// §4.3 migrated streams routed into this shard's pool
    /// (shard-targeted migration only).
    migrated_in: usize,
    /// Last batch size recorded in the batch timeline (dedupes
    /// consecutive identical samples); `None` before the first sample.
    last_batch: Option<usize>,
    /// Cold → Warm → Draining → Retired under autoscaling (outages force
    /// Draining mid-run).
    phase: LifecyclePhase,
    /// Absolute creation time (the first arrival for initial shards), the
    /// start of this shard's shard-seconds accrual.
    created_at: f64,
    /// When a cold shard finishes loading (drives the all-cold routing
    /// fallback); 0.0 for shards created warm.
    ready_at: f64,
    /// Absolute retirement time; `None` while the shard still accrues
    /// shard-seconds.
    retired_at: Option<f64>,
}

impl ShardState {
    fn new(pool: Pool, rtt: f64, phase: LifecyclePhase, created_at: f64, ready_at: f64) -> Self {
        ShardState {
            pool,
            rtt,
            work: 0.0,
            busy: 0.0,
            overcommit_seconds: 0.0,
            delays: Vec::new(),
            admitted: 0,
            migrated_in: 0,
            last_batch: None,
            phase,
            created_at,
            ready_at,
            retired_at: None,
        }
    }
}

struct FleetSim<'a> {
    scenario: &'a Scenario,
    trace: &'a Trace,
    policy: &'a Policy,
    planner: MigrationPlanner,
    fleet: FleetConfig,
    /// Per-shard endpoints (base profile + shard RTT) used for migration
    /// re-prefill sampling once a request is pinned to a shard.
    server_endpoints: Vec<ServerEndpoint>,
    balancer: Box<dyn Balancer>,
    /// Fleet-level balancer stream, disjoint from every per-request
    /// stream (randomized balancers must not perturb latency draws).
    brng: Rng,
    /// The event queue (wheel or heap backend per
    /// `FleetConfig::event_queue`); sequence numbers are assigned at
    /// push, so `queue.pushed()` is the historical `events_processed`.
    queue: EventQueue<EvKind>,
    /// Dense per-stream state (SoA), keyed by trace index.
    arena: StreamArena,
    /// Incrementally maintained shard-selection index for the
    /// deterministic scan balancers (JSQ / least-work): `None` for other
    /// balancers, which snapshot and scan as before. Mutation sites mark
    /// shards dirty ([`FleetSim::touch_shard`]); picks flush and read
    /// the root in O(dirty · log K) instead of rescanning all K shards.
    shard_index: Option<ShardIndex>,
    /// Queue-entry cancellation flags, indexed by request. These live
    /// outside `ReqState` (single source of truth) so `Pool::release`
    /// can consult them while the simulator is otherwise borrowed.
    server_cancelled: Vec<bool>,
    device_cancelled: Vec<bool>,
    shards: Vec<ShardState>,
    /// Shard each server-bound request was balanced onto (None until
    /// arrival, and forever for device-only requests).
    shard_of: Vec<Option<usize>>,
    /// Scratch buffer for the per-arrival balancer snapshot (reused so
    /// the hot path allocates nothing).
    views: Vec<ShardView>,
    device_pool: Pool,
    records: Vec<Option<RequestRecord>>,
    device_delays: Vec<f64>,
    device_busy: f64,
    horizon: f64,
    /// Normalized autoscaling configuration (None = static fleet).
    autoscale: Option<AutoscaleConfig>,
    /// The scaling policy; None for static fleets AND for
    /// `AutoscalerKind::None`, in which case no evaluation events are
    /// scheduled and the run is byte-identical to the static fleet.
    scaler: Option<Box<dyn Autoscaler>>,
    /// Autoscaler decision stream, disjoint from the balancer stream and
    /// every per-request stream.
    arng: Rng,
    /// Fault-injection stream (per-shard degradation spikes), disjoint
    /// from all of the above; never drawn when no fault is configured,
    /// so healthy fleets stay byte-identical.
    frng: Rng,
    /// Requests resolved so far; evaluation events stop rescheduling once
    /// every request resolved, so the event loop terminates.
    resolved_count: usize,
    scale_events: Vec<ScaleEvent>,
    timeline: Vec<ShardCountSample>,
    cold_start_seconds: f64,
    /// Shard occupancy held by request `i`'s migrated-in stream
    /// (shard-targeted migration): the target shard, whether a real slot
    /// was taken, the booked work estimate, and the booking time —
    /// released at `MigrationRelease`.
    migration_booking: Vec<Option<(usize, bool, f64, f64)>>,
    migration_targeted: usize,
    migration_fallbacks: usize,
    outage_requeues: usize,
    /// Per-request prompt lengths (tokens), indexed like the trace —
    /// the admission cost the token-gated pools charge.
    prompt_tokens: Vec<u32>,
    /// Per-shard admission cap the pools were built with (`None` under
    /// continuous batching); autoscaler-provisioned shards reuse it.
    pool_cap: Option<usize>,
    /// Batch-size timeline samples (gated batching modes only; absolute
    /// times, re-based at report build).
    batch_samples: Vec<BatchSample>,
    /// Per-request prompt tokens the *server* pools charge: equal to
    /// `prompt_tokens` except under paged KV, where a prefix-cache hit
    /// shrinks the charge to the uncached suffix. Device pools always
    /// charge the full prompt.
    server_tokens: Vec<u32>,
    /// Per-shard lists of admitted, still-decoding streams whose KV
    /// pages live on that shard (paged KV only; drives decode growth
    /// and preemption victim selection).
    kv_live: Vec<Vec<usize>>,
    /// KV pages currently held by request `i`'s own stream (prefill +
    /// decode growth) on its shard.
    kv_pages_held: Vec<usize>,
    /// Until this absolute time, stream `i` is re-prefilling after a
    /// preemption/failover and neither grows nor gets preempted again.
    kv_suspend_until: Vec<f64>,
    /// Absolute time of request `i`'s *current* `ServerRelease` event.
    /// Preemption and KV failover push a superseding later release; the
    /// handler only honors the event whose timestamp matches (the
    /// stale-release guard), so a slot never double-frees.
    kv_release_at: Vec<f64>,
    /// Whether request `i`'s server release already fired (paged mode).
    kv_release_done: Vec<bool>,
    /// KV pages booked on a §4.3 migration target for request `i`'s
    /// migrated-in stream; freed at `MigrationRelease`.
    kv_mig_pages: Vec<usize>,
    /// Memory-pressure preemptions (evict-and-re-prefill) this run.
    kv_preemptions: usize,
    /// Mid-decode re-prefills forced by a hard outage losing KV.
    kv_forced_reprefills: usize,
    /// Raw generation timeline of request `i`'s server stream, relative
    /// to its arrival (`[0]` = TTFT), captured at resolve under
    /// iteration-level pricing. Empty = not tracked (join-time runs,
    /// device winners, migrated streams). Batch-change repricing
    /// re-stamps the pending suffix in place; the record's delivered
    /// `tbts` are re-derived from it (deferred finalization) when the
    /// stream's release event validly fires.
    gen_times: Vec<Vec<f64>>,
    /// Per-shard lists of streams tracked for iteration-level repricing
    /// (resolved server winners decoding in that shard's batch).
    decode_live: Vec<Vec<usize>>,
    /// Batch-change repricing events applied this run (telemetry).
    reprice_events: u64,
    /// Seconds of release-time *stretch* applied by repricing (batch
    /// grew mid-decode — the ramp direction).
    reprice_stretch_seconds: f64,
    /// Seconds of release-time *shrink* applied by repricing (batch
    /// drained mid-decode).
    reprice_shrink_seconds: f64,
    /// First arrival (absolute); shard-seconds and report timestamps are
    /// measured from here.
    t0: f64,
}

impl<'a> FleetSim<'a> {
    fn push(&mut self, time: f64, kind: EvKind) {
        self.queue.push(time, kind);
    }

    /// Mark shard `s` stale in the incremental balancer index (no-op
    /// when the configured balancer keeps none). Called wherever a
    /// shard's occupancy, queue depth, outstanding work, or lifecycle
    /// phase changes, so the next pick's flush sees fresh leaves.
    fn touch_shard(&mut self, s: usize) {
        if let Some(idx) = &mut self.shard_index {
            idx.mark(s);
        }
    }

    /// Request `i`, borrowed for the trace lifetime (decoupled from
    /// `&self`, so the loop can mutate simulator state while holding it).
    fn req(&self, i: usize) -> &'a crate::trace::Request {
        &self.trace.requests[i]
    }

    fn run(mut self) -> FleetOutcome {
        // Fork per-request RNG streams in trace order (not event order):
        // this pins the root RNG sequence to the trace, matching the
        // legacy engine draw-for-draw. The streams live in the arena and
        // are consumed in place — pre-draw at arrival, resolve later —
        // without the per-request clone the loop used to pay.
        let trace = self.trace;
        let mut root = Rng::new(self.scenario.cfg.seed);
        self.arena.rng = trace.requests.iter().map(|r| root.fork(r.id)).collect();
        for (i, req) in trace.requests.iter().enumerate() {
            self.push(req.arrival, EvKind::Arrival(i));
        }
        // Shard lifetimes (and the report's horizon) are measured from
        // the first arrival.
        self.t0 = trace.requests.first().map_or(0.0, |r| r.arrival);
        for sh in &mut self.shards {
            sh.created_at = self.t0;
        }
        self.record_timeline(self.t0);
        // Outage times are relative to the first arrival. Scheduling them
        // before the first autoscaler evaluation gives outage events the
        // lower sequence number at any shared timestamp, so an outage
        // always fires before an autoscaler evaluation scheduled for the
        // same instant (arrivals, pushed first of all, still precede
        // both — a request arriving exactly at the outage instant is
        // balanced, then immediately re-routed with the rest of the
        // queue).
        if !trace.requests.is_empty() {
            // By index, not by cloned list: `ShardOutage` is `Copy`, so
            // the schedule loop allocates nothing.
            for idx in 0..self.fleet.outages.len() {
                let o = self.fleet.outages[idx];
                if o.at.is_finite() {
                    self.push(self.t0 + o.at.max(0.0), EvKind::Outage(idx));
                }
            }
        }
        if self.scaler.is_some() && !trace.requests.is_empty() {
            let interval = self
                .autoscale
                .as_ref()
                .expect("scaler implies autoscale config")
                .eval_interval;
            self.push(self.t0 + interval, EvKind::AutoscaleEval);
        }
        if let Some(tick) = self.fleet.batching.tick_interval() {
            if !trace.requests.is_empty() {
                self.push(self.t0 + tick, EvKind::BatchTick);
            }
        }

        while let Some((time, kind)) = self.queue.pop() {
            // Autoscaler/failure bookkeeping (evaluation ticks, warm-ups,
            // outage injections) does not advance the workload horizon: a
            // cold start completing after the last token would otherwise
            // dilute utilization and over-bill shard-seconds for every
            // surviving shard. Work a warm-up *admits* still lands in the
            // horizon through its own resolve/release events.
            let bookkeeping = matches!(
                kind,
                EvKind::AutoscaleEval
                    | EvKind::ShardWarm(_)
                    | EvKind::Outage(_)
                    | EvKind::BatchTick
            );
            // Superseded release events — paged preemption/failover and
            // iteration-level repricing both re-time a stream's release
            // by pushing a later (or earlier) event — are dropped
            // *before* the horizon update: a stale timestamp is not a
            // workload time, and honoring it would overstate the
            // horizon whenever repricing shrank a stream (the drain
            // direction). Only the event whose timestamp matches the
            // current booking fires, and only once, so a slot never
            // double-frees.
            if let EvKind::ServerRelease(i) = kind {
                if self.release_guard_active()
                    && (self.kv_release_done[i]
                        || time.total_cmp(&self.kv_release_at[i]) != Ordering::Equal)
                {
                    continue;
                }
            }
            if time.is_finite() && !bookkeeping {
                self.horizon = self.horizon.max(time);
            }
            match kind {
                EvKind::Arrival(i) => {
                    let req = self.req(i);
                    // Arrivals fire in trace order (pushed first, over
                    // nondecreasing times), so the pre-draw column grows
                    // densely.
                    debug_assert_eq!(i, self.arena.pre.len(), "arrival out of trace order");
                    let pre = pre_draw(
                        req,
                        self.policy,
                        &self.scenario.server,
                        &self.scenario.device,
                        &mut self.arena.rng[i],
                    );
                    let needs_server = pre.decision.uses_server();
                    let needs_device = pre.decision.uses_device();
                    self.arena.pre.push(pre);
                    self.arena.needs_server[i] = needs_server;
                    self.arena.needs_device[i] = needs_device;
                    if needs_server {
                        // `assign_shard` may shrink the admission charge
                        // to the uncached prompt suffix (paged-KV prefix
                        // hit), so the server charge reads *after* it.
                        let s = self.assign_shard(i);
                        let tokens = self.server_tokens[i];
                        if self.shards[s].pool.acquire(i, tokens) {
                            self.on_server_admit(i, time);
                        }
                        self.touch_shard(s);
                    }
                    if needs_device
                        && (!self.fleet.device_queueing
                            || self.device_pool.acquire(i, self.prompt_tokens[i]))
                    {
                        self.on_device_grant(i, time);
                    }
                    self.try_resolve(i, time);
                }
                EvKind::ServerRelease(i) => {
                    // Stale (superseded) releases were dropped before
                    // the horizon update above; this one is valid. Mark
                    // it done so preemption, failover, and repricing
                    // stop considering the stream.
                    if self.release_guard_active() {
                        self.kv_release_done[i] = true;
                    }
                    let s = self.shard_of[i].expect("released requests are assigned");
                    // Iteration-level pricing: the stream's delivered
                    // record finalizes from its (possibly re-stamped)
                    // generation timeline only now, when no further
                    // batch change can touch it.
                    self.finalize_stream(i, s);
                    // The stream's KV pages free with its slot — before
                    // the pool release below, so the admit-next scan
                    // sees the freed pages.
                    let held = self.kv_pages_held[i];
                    if held > 0 {
                        self.kv_pages_held[i] = 0;
                        if let Some(g) = self.shards[s].pool.kv_mut() {
                            g.free(held);
                        }
                    }
                    if self.fleet.batching.is_paged() {
                        self.kv_live[s].retain(|&j| j != i);
                    }
                    // The slot holder's service ends here — only now does
                    // its work estimate leave the LeastWork signal.
                    let sample = self.arena.pre[i]
                        .server_sample
                        .expect("server users have a sample");
                    self.shards[s].work -= sample;
                    let next = self
                        .shards[s]
                        .pool
                        .release(&self.server_cancelled, &self.server_tokens);
                    self.touch_shard(s);
                    if let Some(j) = next {
                        self.on_server_admit(j, time);
                        self.try_resolve(j, time);
                    }
                    self.record_batch(s, time);
                    self.maybe_retire(s, time);
                }
                EvKind::DeviceRelease => {
                    let next = self
                        .device_pool
                        .release(&self.device_cancelled, &self.prompt_tokens);
                    if let Some(j) = next {
                        self.on_device_grant(j, time);
                        self.try_resolve(j, time);
                    }
                }
                EvKind::ServerFirstProbe(i) => {
                    let pending = !self.device_cancelled[i]
                        && !self.arena.resolved[i]
                        && self.arena.device_grant[i].is_none();
                    if pending {
                        // The server answered first: leave the device
                        // queue (`device_grant` is None, so with device
                        // queueing on the request is sitting in it).
                        self.device_cancelled[i] = true;
                        if self.fleet.device_queueing {
                            let tokens = self.prompt_tokens[i];
                            self.device_pool.cancel_queued(tokens);
                        }
                        self.try_resolve(i, time);
                    }
                }
                EvKind::DeviceFirstProbe(i) => {
                    let pending = !self.server_cancelled[i]
                        && !self.arena.resolved[i]
                        && self.arena.server_admit[i].is_none();
                    if pending {
                        // The device answered first: abandon the admission
                        // queue (the provider still bills the dispatched
                        // prompt; see `resolve_request`). `server_admit`
                        // is None, so the entry is sitting in its shard's
                        // queue.
                        self.server_cancelled[i] = true;
                        let s = self.shard_of[i].expect("server-bound requests are assigned");
                        let tokens = self.server_tokens[i];
                        self.shards[s].pool.cancel_queued(tokens);
                        self.touch_shard(s);
                        self.try_resolve(i, time);
                        // A draining shard whose last live entry was just
                        // cancelled can retire now.
                        self.maybe_retire(s, time);
                    }
                }
                EvKind::AutoscaleEval => {
                    self.autoscale_eval(time);
                    if self.resolved_count < trace.len() {
                        let interval = self
                            .autoscale
                            .as_ref()
                            .expect("eval events imply autoscale config")
                            .eval_interval;
                        self.push(time + interval, EvKind::AutoscaleEval);
                    }
                }
                EvKind::ShardWarm(s) => self.warm_shard(s, time),
                EvKind::Outage(idx) => {
                    let shard = self.fleet.outages[idx].shard;
                    self.inject_outage(shard, time);
                }
                EvKind::MigrationRelease(i) => {
                    let (s, real_slot, work, booked_at) = self.migration_booking[i]
                        .take()
                        .expect("migration release implies a booking");
                    self.shards[s].work -= work;
                    // Booked occupancy splits by where it sat: real
                    // slots bill into busy-seconds (within capacity),
                    // batch joins into over-commit seconds — keeping
                    // utilization a within-capacity ratio.
                    let held = (time - booked_at).max(0.0);
                    if real_slot {
                        self.shards[s].busy += held;
                    } else {
                        self.shards[s].overcommit_seconds += held;
                    }
                    // KV pages booked for the migrated-in stream free
                    // with its occupancy (before the admit-next scan).
                    let pages = self.kv_mig_pages[i];
                    if pages > 0 {
                        self.kv_mig_pages[i] = 0;
                        if let Some(g) = self.shards[s].pool.kv_mut() {
                            g.free(pages);
                        }
                    }
                    let next = if real_slot {
                        self.shards[s]
                            .pool
                            .release(&self.server_cancelled, &self.server_tokens)
                    } else {
                        self.shards[s]
                            .pool
                            .release_overflow(&self.server_cancelled, &self.server_tokens)
                    };
                    self.touch_shard(s);
                    if let Some(j) = next {
                        self.on_server_admit(j, time);
                        self.try_resolve(j, time);
                    }
                    self.record_batch(s, time);
                    self.maybe_retire(s, time);
                }
                EvKind::BatchTick => {
                    let paged = self.fleet.batching.is_paged();
                    let shard_count = self.shards.len();
                    for s in 0..shard_count {
                        // Retired shards are gone; cold (frozen) shards
                        // cannot admit, so ticking them would only
                        // inflate `prompt_token_capacity` with budget
                        // nothing could use — they start ticking once
                        // warm, with their initial allotment intact.
                        if self.shards[s].phase == LifecyclePhase::Retired
                            || self.shards[s].pool.frozen
                        {
                            continue;
                        }
                        self.shards[s].pool.tick();
                        if paged {
                            // Decode growth first, then preemption if
                            // growth blew past the pool — so admission
                            // below sees the true free-page count.
                            self.kv_tick_shard(s, time);
                        }
                        while let Some(j) = self
                            .shards[s]
                            .pool
                            .try_admit(&self.server_cancelled, &self.server_tokens)
                        {
                            self.on_server_admit(j, time);
                            self.try_resolve(j, time);
                        }
                        self.touch_shard(s);
                    }
                    if self.resolved_count < trace.len() {
                        let interval = self
                            .fleet
                            .batching
                            .tick_interval()
                            .expect("ticks imply a tick-scheduled batching mode");
                        self.push(time + interval, EvKind::BatchTick);
                    }
                }
            }
        }

        let records: Vec<RequestRecord> = self
            .records
            .into_iter()
            .map(|r| r.expect("every request resolves"))
            .collect();
        // Horizon is measured from the first arrival, not absolute time
        // zero, so traces with a delayed start (e.g. session ramp-up) do
        // not dilute utilization with an idle prefix.
        let t0 = self.t0;
        let end = self.horizon.max(t0);
        // Fleet-level aggregates derive from the per-shard accounting —
        // one source of truth (Summary sorts internally, so the shard
        // concatenation order is irrelevant).
        let mut all_delays: Vec<f64> = Vec::new();
        let mut server_busy = 0.0;
        let mut shard_seconds = 0.0;
        let mut release_underflows = self.device_pool.underflows;
        let mut prefix_hits = 0u64;
        let mut prefix_lookups = 0u64;
        let mut prefix_evictions = 0u64;
        let shard_loads: Vec<ShardLoad> = self
            .shards
            .iter()
            .map(|s| {
                all_delays.extend_from_slice(&s.delays);
                server_busy += s.busy;
                release_underflows += s.pool.underflows;
                // Retirement can be stamped by a post-horizon autoscaler
                // tick; clamp so draining never bills MORE than staying
                // warm to the end of the run.
                let shard_end = s.retired_at.unwrap_or(end).min(end);
                let lifetime = (shard_end - s.created_at).max(0.0);
                shard_seconds += lifetime;
                let (prompt_tokens_admitted, prompt_token_capacity) = s.pool.token_totals();
                let (kv_pages_peak, kv_pages_total) = match s.pool.kv() {
                    Some(g) => {
                        let (h, l) = g.prefix_stats();
                        prefix_hits += h;
                        prefix_lookups += l;
                        prefix_evictions += g.prefix_evictions();
                        (g.peak_pages(), g.pages_total())
                    }
                    None => (0, 0),
                };
                ShardLoad {
                    queue_delay: Summary::of(&s.delays),
                    busy_seconds: s.busy,
                    overcommit_seconds: s.overcommit_seconds,
                    admitted: s.admitted,
                    slots: s.pool.cap,
                    migrated_in: s.migrated_in,
                    lifetime_seconds: lifetime,
                    peak_in_use: s.pool.peak_in_use,
                    prompt_tokens_admitted,
                    prompt_token_capacity,
                    kv_pages_peak,
                    kv_pages_total,
                }
            })
            .collect();
        // Timeline and scale-event timestamps are reported relative to
        // the first arrival, like the horizon.
        let rel = |t: f64| (t - t0).max(0.0);
        let shard_timeline = self
            .timeline
            .iter()
            .map(|s| ShardCountSample {
                time: rel(s.time),
                ..*s
            })
            .collect();
        let scale_events = self
            .scale_events
            .iter()
            .map(|e| ScaleEvent {
                time: rel(e.time),
                ..*e
            })
            .collect();
        let batch_timeline = self
            .batch_samples
            .iter()
            .map(|b| BatchSample {
                time: rel(b.time),
                ..*b
            })
            .collect();
        let load = LoadReport {
            server_queue_delay: Summary::of(&all_delays),
            device_queue_delay: Summary::of(&self.device_delays),
            server_busy_seconds: server_busy,
            device_busy_seconds: self.device_busy,
            horizon: (self.horizon - t0).max(0.0),
            server_slots: self.fleet.server_slots,
            shards: shard_loads,
            shard_timeline,
            scale_events,
            cold_start_seconds: self.cold_start_seconds,
            shard_seconds,
            events_processed: self.queue.pushed(),
            migration_targeted: self.migration_targeted,
            migration_fallbacks: self.migration_fallbacks,
            outage_requeues: self.outage_requeues,
            release_underflows,
            batch_timeline,
            prefix_hits,
            prefix_lookups,
            kv_preemptions: self.kv_preemptions,
            kv_forced_reprefills: self.kv_forced_reprefills,
            reprice_events: self.reprice_events,
            reprice_stretch_seconds: self.reprice_stretch_seconds,
            reprice_shrink_seconds: self.reprice_shrink_seconds,
            prefix_evictions,
        };
        FleetOutcome { records, load }
    }

    /// Rebuild the reusable per-shard snapshot buffer (`self.views`);
    /// returns whether any shard currently admits new work.
    fn snapshot_views(&mut self) -> bool {
        self.views.clear();
        let mut any_admitting = false;
        for sh in &self.shards {
            let admitting = sh.phase == LifecyclePhase::Warm;
            any_admitting |= admitting;
            self.views.push(ShardView {
                in_use: sh.pool.in_use,
                queued: sh.pool.live_queued(),
                slots: sh.pool.cap,
                work: sh.work,
                queued_tokens: sh.pool.queued_prompt_tokens(),
                admitting,
            });
        }
        any_admitting
    }

    /// Decode-gap multiplier for a stream joining shard `s`'s batch
    /// right now (the stream itself already counted in `in_use`). 1.0
    /// under slot semantics — legacy streams are never repriced.
    fn batch_slowdown(&self, s: usize) -> f64 {
        match self.fleet.batching {
            BatchingMode::Continuous(c) => c.curve.slowdown(self.shards[s].pool.in_use),
            BatchingMode::PagedKv(k) => k.curve.slowdown(self.shards[s].pool.in_use),
            BatchingMode::SlotLegacy => 1.0,
        }
    }

    /// Whether this run re-prices running decodes on batch change:
    /// iteration-level pricing under a gated batching mode. Slot-legacy
    /// streams are never repriced regardless of the pricing mode.
    fn reprice_active(&self) -> bool {
        self.fleet.pricing == PricingMode::IterationLevel && self.fleet.batching.batched()
    }

    /// Whether `ServerRelease` events can be superseded and must pass
    /// the timestamp guard: paged KV stretches releases at preemption
    /// and failover, iteration-level repricing moves them on any batch
    /// change.
    fn release_guard_active(&self) -> bool {
        self.fleet.batching.is_paged() || self.reprice_active()
    }

    /// Append a batch-size sample for shard `s` if the size changed
    /// (continuous batching only; legacy runs record nothing, keeping
    /// their load reports byte-identical). Under iteration-level
    /// pricing a size change is exactly the repricing trigger: the
    /// slowdown curve reads only the batch *size*, so same-size
    /// composition churn (one stream leaves as another admits) is a
    /// semantic no-op and is skipped by the dedupe.
    fn record_batch(&mut self, s: usize, now: f64) {
        if !self.fleet.batching.batched() {
            return;
        }
        let batch = self.shards[s].pool.in_use;
        if self.shards[s].last_batch == Some(batch) {
            return;
        }
        self.shards[s].last_batch = Some(batch);
        self.batch_samples.push(BatchSample {
            time: now,
            shard: s,
            batch,
        });
        if self.reprice_active() {
            self.reprice_shard(s, now);
        }
    }

    /// Re-price every tracked stream decoding in shard `s`'s batch at
    /// the batch's *current* slowdown (iteration-level pricing).
    fn reprice_shard(&mut self, s: usize, now: f64) {
        let new_slow = self.batch_slowdown(s);
        // Snapshot the tracked list: repricing itself never changes
        // membership (that happens at resolve/release/failover).
        let live = std::mem::take(&mut self.decode_live[s]);
        for &j in &live {
            self.reprice_stream(j, s, now, new_slow);
        }
        self.decode_live[s] = live;
    }

    /// Re-stamp the pending (un-generated) suffix of tracked stream
    /// `j`'s generation timeline at slowdown `new_slow`, supersede its
    /// release event, and re-bill the slot seconds. The in-flight gap
    /// splits piecewise at `now`: the elapsed part is history, only the
    /// remainder re-scales. Skips streams that are suspended
    /// (re-prefilling — the stall is not decode time), fully generated,
    /// or already priced at bit-identical slowdown — the latter keeps
    /// flat curves and batch-size-1 runs byte-identical with zero
    /// telemetry.
    fn reprice_stream(&mut self, j: usize, s: usize, now: f64, new_slow: f64) {
        if self.kv_release_done[j] || now < self.kv_suspend_until[j] {
            return;
        }
        let old_slow = self.arena.decode_slowdown[j];
        if new_slow.to_bits() == old_slow.to_bits() {
            return;
        }
        let rel = now - self.trace.requests[j].arrival;
        let gen = &mut self.gen_times[j];
        debug_assert!(!gen.is_empty(), "tracked streams carry a timeline");
        // First still-pending token (strictly after `now`).
        let cur = gen.iter().take_while(|&&t| t <= rel).count();
        if cur >= gen.len() {
            // Fully generated; only the already-scheduled release
            // remains.
            return;
        }
        let ratio = new_slow / old_slow;
        let old_last = *gen.last().unwrap();
        if cur == 0 {
            // Prefill still running: TTFT is untouched, every decode
            // gap re-scales whole.
            let base = gen[0];
            for t in gen.iter_mut().skip(1) {
                *t = base + (*t - base) * ratio;
            }
        } else {
            // Split the in-flight gap at `now`; later gaps scale whole.
            let old_pivot = gen[cur];
            let new_pivot = rel + (old_pivot - rel) * ratio;
            gen[cur] = new_pivot;
            for t in gen.iter_mut().skip(cur + 1) {
                *t = new_pivot + (*t - old_pivot) * ratio;
            }
        }
        let delta = *gen.last().unwrap() - old_last;
        self.arena.decode_slowdown[j] = new_slow;
        // Supersede the pending release: the old event's timestamp no
        // longer matches `kv_release_at`, so the stale guard drops it.
        // A shrink past `now` clamps to `now` (the slot cannot free in
        // the past), keeping the stamped time and the pushed event in
        // exact agreement.
        let old_at = self.kv_release_at[j];
        let at = (old_at + delta).max(now);
        let shift = at - old_at;
        self.shards[s].busy += shift;
        self.kv_release_at[j] = at;
        self.push(at, EvKind::ServerRelease(j));
        self.reprice_events += 1;
        if shift >= 0.0 {
            self.reprice_stretch_seconds += shift;
        } else {
            self.reprice_shrink_seconds -= shift;
        }
    }

    /// Deferred finalization of tracked stream `i` on shard `s` at its
    /// valid release: re-derive the delivered record from the (possibly
    /// re-stamped) generation timeline and extend the horizon to the
    /// last delivered token. When no repricing touched the stream the
    /// timeline is bit-identical to the one the resolve step smoothed,
    /// so the record — and every downstream byte — is unchanged. A
    /// no-op for untracked streams (empty timeline).
    fn finalize_stream(&mut self, i: usize, s: usize) {
        let gen = std::mem::take(&mut self.gen_times[i]);
        if gen.is_empty() {
            return;
        }
        self.decode_live[s].retain(|&j| j != i);
        let r_c = self.scenario.cfg.migration.consumption_rate;
        let d = delivery::smooth(&gen, r_c);
        let rec = self.records[i]
            .as_mut()
            .expect("tracked streams are resolved");
        rec.tbts = d.tbts;
        rec.delay_num = d.delay_num;
        let done = self.trace.requests[i].arrival + rec.ttft + rec.tbts.iter().sum::<f64>();
        if done.is_finite() {
            self.horizon = self.horizon.max(done);
        }
    }

    /// Balance server-bound request `i` onto a shard, apply any
    /// configured per-shard degradation to its pre-drawn sample, and
    /// book its work estimate. With one shard the balancer (and its RNG
    /// stream) is bypassed entirely, preserving byte-identical K=1
    /// replays. Cold, draining, and retired shards are flagged
    /// non-admitting; should every shard be non-admitting (unreachable
    /// while the autoscaler keeps `min_shards ≥ 1` warm, but handled
    /// defensively), the request joins the cold shard that becomes
    /// ready soonest.
    fn assign_shard(&mut self, i: usize) -> usize {
        let s = if self.shards.len() == 1 {
            0
        } else if self.shard_index.is_some() {
            // JSQ / least-work: answer the argmin from the incremental
            // index instead of snapshotting and rescanning all K shards.
            // Neither balancer consumes randomness, so skipping
            // `Balancer::pick` leaves the fleet balancer stream — and
            // therefore every other draw — byte-identical.
            self.pick_indexed()
        } else {
            let any_admitting = self.snapshot_views();
            if any_admitting {
                let pick = self.balancer.pick(&self.views, &mut self.brng);
                assert!(
                    pick < self.shards.len(),
                    "balancer {} violated its contract: picked shard {pick} of {}",
                    self.balancer.name(),
                    self.shards.len()
                );
                debug_assert!(
                    self.views[pick].admitting,
                    "balancer {} routed to a non-admitting shard {pick}",
                    self.balancer.name()
                );
                pick
            } else {
                self.earliest_ready_shard()
            }
        };
        self.shard_of[i] = Some(s);
        let mut sample = self.arena.pre[i]
            .server_sample
            .expect("server users have a sample");
        // Per-shard degradation: landing on a faulty shard may multiply
        // the pre-drawn prefill sample by an extra spike (drawn from the
        // dedicated fault stream). Applied here — before the work
        // booking, the first-token probe, or the resolve step read the
        // sample — so every consumer sees the degraded value, the
        // LeastWork/queue-delay oracles included.
        if let Some(&Some(f)) = self.fleet.shard_faults.get(s) {
            if self.frng.chance(f.spike_prob) {
                let base = sample;
                sample *= self.frng.lognormal(f.spike_scale.max(1e-12).ln(), 0.5);
                self.arena.pre[i].server_sample = Some(sample);
                self.arena.base_sample[i] = Some(base);
            }
        }
        sample = self.apply_prefix_cache(i, s, sample);
        self.shards[s].work += sample;
        self.touch_shard(s);
        s
    }

    /// Paged-KV prefix-cache lookup for request `i` landing on shard
    /// `s`: a hit scales the pre-drawn prefill sample down to the
    /// uncached fraction and shrinks the admission charge
    /// (`server_tokens`) to the uncached suffix. Deterministic and
    /// RNG-free; a no-op (returning `sample` unchanged) outside paged
    /// mode, so other modes stay byte-identical. Returns the sample
    /// every downstream consumer should see.
    fn apply_prefix_cache(&mut self, i: usize, s: usize, sample: f64) -> f64 {
        if !self.fleet.batching.is_paged() {
            return sample;
        }
        let len = self.prompt_tokens[i];
        let cached = match self.shards[s].pool.kv_mut() {
            Some(g) => g.prefix_lookup(len),
            None => 0,
        };
        if cached == 0 {
            return sample;
        }
        // Remember the full-prefill draw: an outage re-route restores
        // it (the cached prefix lived on this shard, not the stream)
        // and re-runs the lookup against the new home's index.
        if self.arena.base_sample[i].is_none() {
            self.arena.base_sample[i] = Some(sample);
        }
        let scaled = sample * (1.0 - cached as f64 / len as f64);
        self.arena.pre[i].server_sample = Some(scaled);
        self.server_tokens[i] = (len - cached).max(1);
        scaled
    }

    /// O(dirty · log K) shard pick through the incremental index: flush
    /// every shard marked stale since the last pick (recomputing its
    /// leaf from live pool/work/phase state — exactly what a
    /// [`ShardView`] snapshot would report), then read the tournament
    /// root. A non-admitting root means no shard admits, the same
    /// degraded path the scan balancers take. Debug builds re-derive the
    /// pick from a full snapshot + linear scan and assert equality.
    fn pick_indexed(&mut self) -> usize {
        let jsq = self.fleet.balancer == BalancerKind::JoinShortestQueue;
        let idx = self
            .shard_index
            .as_mut()
            .expect("indexed pick requires an index");
        while let Some(s) = idx.pop_dirty() {
            let sh = &self.shards[s];
            let admitting = sh.phase == LifecyclePhase::Warm;
            // JSQ orders on outstanding = in_use + queued; counts are
            // tiny relative to 2^53, so the f64 key orders identically.
            let key = if jsq {
                (sh.pool.in_use + sh.pool.live_queued()) as f64
            } else {
                sh.work
            };
            idx.update(s, admitting, key);
        }
        let root = idx.root();
        let pick = if root.admitting {
            root.shard
        } else {
            self.earliest_ready_shard()
        };
        #[cfg(debug_assertions)]
        {
            use crate::sim::balancer::argmin_admitting;
            let any_admitting = self.snapshot_views();
            assert_eq!(
                any_admitting, root.admitting,
                "shard index admitting flag diverged from the snapshot"
            );
            if any_admitting {
                let linear = if jsq {
                    argmin_admitting(&self.views, |a, b| a.outstanding() < b.outstanding())
                } else {
                    argmin_admitting(&self.views, |a, b| {
                        a.work.total_cmp(&b.work) == Ordering::Less
                    })
                };
                assert_eq!(
                    pick,
                    linear,
                    "shard index diverged from the linear {} scan",
                    self.fleet.balancer.label()
                );
            }
        }
        pick
    }

    /// The cold shard with the earliest warm-up time (ties to the lowest
    /// index); degrades to the first non-retired shard — never a retired
    /// pool, which must take no new work — when nothing is even cold.
    fn earliest_ready_shard(&self) -> usize {
        let mut best: Option<usize> = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if sh.phase != LifecyclePhase::Cold {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => sh.ready_at.total_cmp(&self.shards[b].ready_at) == Ordering::Less,
            };
            if better {
                best = Some(i);
            }
        }
        best.unwrap_or_else(|| {
            // `maybe_retire` keeps at least one shard non-retired, so
            // this position exists whenever the fleet has run at all.
            self.shards
                .iter()
                .position(|sh| sh.phase != LifecyclePhase::Retired)
                .unwrap_or(0)
        })
    }

    fn on_server_admit(&mut self, i: usize, now: f64) {
        let arrival = self.trace.requests[i].arrival;
        let s = self.shard_of[i].expect("admitted requests are assigned");
        let rtt = self.shards[s].rtt;
        let dev_cancelled = self.device_cancelled[i];
        // Price the stream's decode at the batch it joins (itself
        // included — the pool already counted it). Frozen at admission:
        // later joins see the bigger batch, this stream is not repriced.
        let slowdown = self.batch_slowdown(s);
        self.arena.server_admit[i] = Some(now);
        self.arena.decode_slowdown[i] = slowdown;
        let sample = self.arena.pre[i]
            .server_sample
            .expect("server users have a sample");
        let device_pending = self.arena.needs_device[i]
            && self.arena.device_grant[i].is_none()
            && !dev_cancelled;
        let delay = (now - arrival).max(0.0);
        self.shards[s].delays.push(delay);
        self.shards[s].admitted += 1;
        if self.fleet.batching.is_paged() {
            // The pool's gate already allocated this stream's prefill
            // pages at `admit_now`; mirror the count here so release,
            // preemption, and failover free exactly what was taken —
            // then index the prompt for future prefix hits.
            let tokens = self.server_tokens[i];
            let full_len = self.trace.requests[i].prompt_len;
            if let Some(g) = self.shards[s].pool.kv_mut() {
                self.kv_pages_held[i] = g.pages_for(tokens);
                g.prefix_insert(full_len);
            }
            self.kv_live[s].push(i);
        }
        self.record_batch(s, now);
        if device_pending {
            // First token lands at admit + intrinsic prefill (+ shard
            // RTT); if the device is still queued then, it is skipped
            // (§4.2).
            self.push(now + sample + rtt, EvKind::ServerFirstProbe(i));
        }
    }

    fn on_device_grant(&mut self, i: usize, now: f64) {
        let req = self.req(i);
        let srv_cancelled = self.server_cancelled[i];
        self.arena.device_grant[i] = Some(now);
        let device_wait = match self.arena.pre[i].decision {
            crate::coordinator::dispatch::Decision::Both { device_wait } => device_wait,
            _ => 0.0,
        };
        let dev_start_rel = device_wait.max((now - req.arrival).max(0.0));
        let dev_first_abs = req.arrival + dev_start_rel + self.arena.pre[i].dev_prefill_dur;
        let server_pending = self.arena.needs_server[i]
            && self.arena.server_admit[i].is_none()
            && !srv_cancelled;
        self.device_delays.push((now - req.arrival).max(0.0));
        if server_pending && dev_first_abs.is_finite() {
            self.push(dev_first_abs, EvKind::DeviceFirstProbe(i));
        }
    }

    // -----------------------------------------------------------------
    // Autoscaling
    // -----------------------------------------------------------------

    /// One autoscaler evaluation: snapshot the fleet, ask the policy,
    /// clamp the action to `[min_shards, max_shards]`, and apply it.
    fn autoscale_eval(&mut self, now: f64) {
        let statuses: Vec<ShardStatus> = self
            .shards
            .iter()
            .map(|sh| ShardStatus {
                view: ShardView {
                    in_use: sh.pool.in_use,
                    queued: sh.pool.live_queued(),
                    slots: sh.pool.cap,
                    work: sh.work,
                    queued_tokens: sh.pool.queued_prompt_tokens(),
                    admitting: sh.phase == LifecyclePhase::Warm,
                },
                phase: sh.phase,
            })
            .collect();
        let cfg = *self.autoscale.as_ref().expect("eval implies config");
        let view = FleetView {
            now,
            shards: &statuses,
            slots_per_shard: self.fleet.server_slots,
            min_shards: cfg.min_shards,
            max_shards: cfg.max_shards,
            prefill_tokens_per_sec: self.fleet.batching.admission_tokens_per_sec(),
        };
        let action = self
            .scaler
            .as_mut()
            .expect("eval implies a scaling policy")
            .evaluate(&view, &mut self.arng);
        match action {
            ScaleAction::Hold => {}
            ScaleAction::ScaleOut { shards } => self.scale_out(shards, now, &cfg),
            ScaleAction::ScaleIn { shards } => self.scale_in(shards, now, &cfg),
        }
    }

    /// Provision up to `n` cold shards, keeping the total *paid-for*
    /// fleet (everything short of retired — draining victims still bill
    /// shard-seconds) within `max_shards`. Each new shard admits nothing
    /// until its load-time delay — from the configured `ColdStartSpec` —
    /// elapses.
    fn scale_out(&mut self, n: usize, now: f64, cfg: &AutoscaleConfig) {
        let paid_for = self
            .shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .count();
        let room = cfg.max_shards.saturating_sub(paid_for);
        for _ in 0..n.min(room) {
            let ready = now + cfg.cold_start.delay();
            let idx = self.shards.len();
            // New replicas are homogeneous (no extra RTT) and share the
            // base server profile (and the fleet's batching mode, with
            // a fresh gate — a new shard starts with an empty KV pool
            // and a cold prefix index).
            let gate = make_gate(&self.fleet.batching);
            self.shards.push(ShardState::new(
                Pool::new_frozen(self.pool_cap).with_gate_kind(gate),
                0.0,
                LifecyclePhase::Cold,
                now,
                ready,
            ));
            self.kv_live.push(Vec::new());
            self.decode_live.push(Vec::new());
            self.server_endpoints.push(self.scenario.server.clone());
            self.scale_events.push(ScaleEvent {
                time: now,
                shard: idx,
                kind: ScaleEventKind::ScaleOut,
            });
            self.push(ready, EvKind::ShardWarm(idx));
        }
        // The index's leaf capacity is sized to the shard count: rebuild
        // it all-dirty, so the next pick flushes every shard (including
        // the new cold ones) from live state.
        if self.shard_index.is_some() {
            self.shard_index = Some(ShardIndex::new(self.shards.len()));
        }
        self.record_timeline(now);
    }

    /// Drain up to `n` warm shards, never dropping below `min_shards`
    /// warm (so the balancer always has an admitting candidate). The
    /// victim is the warm shard with the least outstanding work; ties
    /// drain the newest shard first.
    fn scale_in(&mut self, n: usize, now: f64, cfg: &AutoscaleConfig) {
        for _ in 0..n {
            let warm: Vec<usize> = self
                .shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.phase == LifecyclePhase::Warm)
                .map(|(i, _)| i)
                .collect();
            if warm.len() <= cfg.min_shards.max(1) {
                break;
            }
            let mut victim = warm[0];
            for &i in &warm[1..] {
                // Least outstanding estimated service seconds (the same
                // signal LeastWork balances on); exact ties — typically
                // idle shards at 0.0 — drain the newest first.
                match self.shards[i].work.total_cmp(&self.shards[victim].work) {
                    Ordering::Less => victim = i,
                    Ordering::Equal if i > victim => victim = i,
                    _ => {}
                }
            }
            self.shards[victim].phase = LifecyclePhase::Draining;
            self.touch_shard(victim);
            self.scale_events.push(ScaleEvent {
                time: now,
                shard: victim,
                kind: ScaleEventKind::DrainStart,
            });
            // An already-empty victim retires immediately.
            self.maybe_retire(victim, now);
        }
        self.record_timeline(now);
    }

    /// A cold shard finished loading: unfreeze its pool, join the
    /// balanced set, and admit anything already queued on it.
    fn warm_shard(&mut self, s: usize, now: f64) {
        if self.shards[s].phase != LifecyclePhase::Cold {
            return;
        }
        self.shards[s].phase = LifecyclePhase::Warm;
        self.shards[s].pool.frozen = false;
        self.touch_shard(s);
        self.cold_start_seconds += (now - self.shards[s].created_at).max(0.0);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::WarmUp,
        });
        self.record_timeline(now);
        while let Some(j) = self
            .shards[s]
            .pool
            .try_admit(&self.server_cancelled, &self.server_tokens)
        {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
    }

    /// A draining shard retires once its last admission released and no
    /// live entry remains queued; retirement stops shard-seconds accrual
    /// (and drops the shard from the timeline's provisioned count).
    ///
    /// The **last** non-retired replica never retires: with every other
    /// shard gone (an outage on a K=1 fleet, or a fleet-wide failure),
    /// future arrivals still have to land somewhere, so the survivor
    /// keeps draining — and billing shard-seconds — to the end of the
    /// run instead of serving traffic "after" retirement (which would
    /// put busy-seconds past its lifetime and push utilization over 1).
    /// Autoscaler scale-in always leaves `min_shards ≥ 1` warm, so this
    /// guard never fires on the PR-3 paths.
    fn maybe_retire(&mut self, s: usize, now: f64) {
        let others_alive = self
            .shards
            .iter()
            .enumerate()
            .any(|(i, sh)| i != s && sh.phase != LifecyclePhase::Retired);
        if !others_alive {
            return;
        }
        let sh = &mut self.shards[s];
        let drained = sh.phase == LifecyclePhase::Draining
            && sh.pool.in_use == 0
            && sh.pool.live_queued() == 0;
        if !drained {
            return;
        }
        sh.phase = LifecyclePhase::Retired;
        sh.retired_at = Some(now);
        self.touch_shard(s);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::Retire,
        });
        self.record_timeline(now);
    }

    /// Injected failure: force shard `s` into Draining, re-route its
    /// queued streams, and let in-flight admissions finish (connection
    /// draining) before the shard retires. Idempotent by construction —
    /// a shard already Draining (e.g. an autoscaler scale-in victim) or
    /// Retired is left untouched, so an outage racing a drain can never
    /// double-retire or double-bill shard-seconds.
    fn inject_outage(&mut self, s: usize, now: f64) {
        if s >= self.shards.len()
            || matches!(
                self.shards[s].phase,
                LifecyclePhase::Draining | LifecyclePhase::Retired
            )
        {
            return;
        }
        // A cold victim's pending warm-up becomes a no-op (`warm_shard`
        // guards on phase); unfreeze the pool so drain semantics — serve
        // whatever cannot be re-routed — still apply.
        self.shards[s].phase = LifecyclePhase::Draining;
        self.shards[s].pool.frozen = false;
        self.touch_shard(s);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::Outage,
        });
        let victims = self.shards[s].pool.drain_queue(&self.server_cancelled);
        for j in victims {
            self.requeue(j, s, now);
        }
        // KV-aware hard failover: in paged mode the dead shard's
        // in-flight KV is lost — every mid-decode stream it was serving
        // must re-prefill, at a migration target when one admits
        // (forced §4.3 migration) or in place on the draining source
        // otherwise.
        if self.fleet.batching.is_paged() {
            self.kv_outage_failover(s, now);
        }
        // Single-shard corner: victims with nowhere to go stayed on the
        // draining shard — admit what spare capacity allows so the run
        // always terminates (a drained-but-queued cold pool would
        // otherwise never grant).
        while let Some(j) = self
            .shards[s]
            .pool
            .try_admit(&self.server_cancelled, &self.server_tokens)
        {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
        self.record_timeline(now);
        self.maybe_retire(s, now);
    }

    /// Re-route a queued (never-admitted) stream off a failed shard —
    /// the token-level view of "migrate the dead shard's pending work".
    /// The placement follows the fleet's migration-targeting mode:
    /// least-work-with-estimate under `ShardTargeted` (victims spread
    /// across survivors, each placement visible to the next), the first
    /// admitting shard under `BaseEndpoint` (the paper's "one server
    /// target" view — every victim piles onto the same replacement).
    /// With no admitting shard anywhere the victim joins the
    /// soonest-ready cold shard; with no live alternative at all it
    /// stays on the draining source, which serves out its queue.
    fn requeue(&mut self, j: usize, from: usize, now: f64) {
        let sample = self.arena.pre[j]
            .server_sample
            .expect("server users have a sample");
        let any_admitting = self.snapshot_views();
        let target = if any_admitting {
            match self.fleet.migration_targeting {
                MigrationTargeting::ShardTargeted => {
                    pick_reprefill_target(&self.views, |i| {
                        self.shards[i].rtt + self.reprefill_queue_delay(i, None, false, 0.0)
                    })
                    .expect("an admitting shard exists")
                }
                MigrationTargeting::BaseEndpoint => self
                    .views
                    .iter()
                    .position(|v| v.admitting)
                    .expect("an admitting shard exists"),
            }
        } else {
            let cold = self.earliest_ready_shard();
            if self.shards[cold].phase == LifecyclePhase::Cold {
                cold
            } else {
                from
            }
        };
        self.shard_of[j] = Some(target);
        self.shards[from].work -= sample;
        self.touch_shard(from);
        // A spike drawn from the dead shard's fault belongs to that
        // shard, not the stream: moving to a new home restores the
        // pre-fault draw and rolls the *target's* fault instead (all
        // from the fault stream, so healthy configs are untouched).
        let mut new_sample = sample;
        if target != from {
            if let Some(base) = self.arena.base_sample[j] {
                new_sample = base;
                self.arena.base_sample[j] = None;
            }
            if let Some(&Some(f)) = self.fleet.shard_faults.get(target) {
                if self.frng.chance(f.spike_prob) {
                    let base = new_sample;
                    new_sample *= self.frng.lognormal(f.spike_scale.max(1e-12).ln(), 0.5);
                    self.arena.base_sample[j] = Some(base);
                }
            }
            self.arena.pre[j].server_sample = Some(new_sample);
            // The cached prefix lived on the dead shard: reset the
            // admission charge to the full prompt, then consult the new
            // home's own index (paged mode only; no-ops otherwise).
            self.server_tokens[j] = self.prompt_tokens[j];
            new_sample = self.apply_prefix_cache(j, target, new_sample);
            self.outage_requeues += 1;
        }
        self.shards[target].work += new_sample;
        let tokens = self.server_tokens[j];
        if self.shards[target].pool.acquire(j, tokens) {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
        self.touch_shard(target);
    }

    /// Predicted admission delay a §4.3 re-prefill pays on shard `t`,
    /// folded into the `t_m` estimate and the reprefill-target pick.
    /// Audited against actual admission behavior (this PR's bugfix
    /// sweep):
    ///
    /// * a migrated stream books via [`Pool::acquire_overflow`], so with
    ///   a real slot spare it admits instantly — the estimate is exactly
    ///   0 (the old work-over-capacity formula charged phantom delay on
    ///   idle shards, see the `idle_fleet` engine-level test);
    /// * the migrating stream's own slot booking no longer counts as
    ///   queued-ahead work when it targets its own shard (the off-by-one
    ///   that priced the stream into its own queue);
    /// * under continuous batching the backlog is priced in tokens —
    ///   queued prompt tokens over the shard's admission token rate.
    fn reprefill_queue_delay(
        &self,
        t: usize,
        own_shard: Option<usize>,
        own_booked: bool,
        own_sample: f64,
    ) -> f64 {
        if let Some(rate) = self.fleet.batching.admission_tokens_per_sec() {
            let queued = self.shards[t].pool.queued_prompt_tokens();
            if self.reprice_active() {
                // Iteration-level pricing: the backlog ahead drains at
                // the pace the *live* batch actually decodes, so the
                // estimate scales by the target's current slowdown
                // (×1.0 — bit-exact — on flat curves, keeping
                // join-time parity).
                return self.planner.queue_delay_estimate_tokens_at_batch(
                    queued,
                    rate,
                    self.batch_slowdown(t),
                );
            }
            return self.planner.queue_delay_estimate_tokens(queued, rate);
        }
        let pool = &self.shards[t].pool;
        let spare = match pool.cap {
            Some(cap) => pool.in_use < cap,
            None => true,
        };
        if spare {
            return 0.0;
        }
        let own = match own_shard {
            Some(s) if s == t && own_booked => own_sample,
            _ => 0.0,
        };
        self.planner
            .queue_delay_estimate((self.shards[t].work - own).max(0.0), pool.cap)
    }

    // -----------------------------------------------------------------
    // Paged KV: decode growth, memory-pressure preemption, failover
    // -----------------------------------------------------------------

    /// Tokens of request `j`'s stream emitted by `now`. Tracked streams
    /// (iteration-level pricing) count on their raw *generation*
    /// timeline — KV pages grow with generated tokens, and the
    /// provisional record still holds resolve-time delivery; everything
    /// else walks the resolved record's delivery timeline (TTFT, then
    /// the inter-token gaps). 0 before the first token or for
    /// unresolved streams.
    fn tokens_emitted(&self, j: usize, now: f64) -> usize {
        if !self.gen_times[j].is_empty() {
            let rel = now - self.trace.requests[j].arrival;
            return self.gen_times[j].iter().take_while(|&&t| t <= rel).count();
        }
        let rec = match &self.records[j] {
            Some(r) => r,
            None => return 0,
        };
        let mut t = self.trace.requests[j].arrival + rec.ttft;
        if t > now {
            return 0;
        }
        let mut n = 1usize;
        for &gap in &rec.tbts {
            t += gap;
            if t > now {
                break;
            }
            n += 1;
        }
        n
    }

    /// Paged-KV per-tick maintenance for shard `s`: grow each live
    /// decode stream's page footprint to cover the tokens it has
    /// emitted (one page per `block_tokens`), then resolve memory
    /// pressure by preempting lowest-priority streams (latest arrival
    /// first) until the ledger fits the pool again — or no eligible
    /// victim remains.
    fn kv_tick_shard(&mut self, s: usize, now: f64) {
        let live: Vec<usize> = self.kv_live[s].clone();
        for j in live {
            if !self.arena.resolved[j]
                || self.kv_release_done[j]
                || now < self.kv_suspend_until[j]
            {
                continue;
            }
            let emitted = self.tokens_emitted(j, now);
            let total =
                (self.server_tokens[j] as u64 + emitted as u64).min(u32::MAX as u64) as u32;
            let held = self.kv_pages_held[j];
            if let Some(g) = self.shards[s].pool.kv_mut() {
                let target = g.pages_for(total);
                if target > held {
                    g.alloc(target - held);
                    self.kv_pages_held[j] = target;
                }
            }
        }
        while self
            .shards[s]
            .pool
            .kv()
            .map_or(false, |g| g.over_capacity())
        {
            match self.kv_victim(s, now) {
                Some(j) => self.kv_preempt(j, s, now),
                None => break,
            }
        }
    }

    /// The preemption victim on shard `s`: the *latest-arriving*
    /// (highest-index) live stream that is resolved, mid-decode (first
    /// token out, last token pending), server-delivered, unmigrated,
    /// not already re-prefilling, and actually holding pages.
    fn kv_victim(&self, s: usize, now: f64) -> Option<usize> {
        let mut best: Option<usize> = None;
        for &j in &self.kv_live[s] {
            if !self.arena.resolved[j]
                || self.kv_release_done[j]
                || now < self.kv_suspend_until[j]
                || self.kv_pages_held[j] == 0
            {
                continue;
            }
            let rec = match &self.records[j] {
                Some(r) => r,
                None => continue,
            };
            if rec.winner != EndpointKind::Server || rec.migrated {
                continue;
            }
            let emitted = self.tokens_emitted(j, now);
            if emitted == 0 || emitted > rec.tbts.len() {
                continue;
            }
            if best.map_or(true, |b| j > b) {
                best = Some(j);
            }
        }
        best
    }

    /// Evict-and-re-prefill stream `j` on shard `s`: free its pages,
    /// charge the full-context recompute against the shard's chunk
    /// budget, and stretch the stream's current inter-token gap by the
    /// deterministic re-prefill delay. The pending release event is
    /// superseded by a later one (the stale-release guard drops the old
    /// timestamp), so the no-gaps/no-dups invariant holds: one gap
    /// stretches, token counts never change.
    fn kv_preempt(&mut self, j: usize, s: usize, now: f64) {
        let emitted = self.tokens_emitted(j, now);
        debug_assert!(emitted >= 1, "preemption victims are mid-decode");
        let reprefill =
            (self.server_tokens[j] as u64 + emitted as u64).min(u32::MAX as u64) as u32;
        let rate = self
            .fleet
            .batching
            .admission_tokens_per_sec()
            .expect("paged mode has an admission rate");
        let delta = reprefill as f64 / rate;
        if self.gen_times[j].is_empty() {
            let done = {
                let rec = self.records[j].as_mut().expect("victims are resolved");
                rec.tbts[emitted - 1] += delta;
                self.trace.requests[j].arrival + rec.ttft + rec.tbts.iter().sum::<f64>()
            };
            if done.is_finite() {
                self.horizon = self.horizon.max(done);
            }
        } else {
            // Tracked stream (iteration-level pricing): the stall
            // shifts the pending generation suffix; the delivered
            // record — and the horizon — pick it up at finalization.
            let rel = now - self.trace.requests[j].arrival;
            for t in self.gen_times[j].iter_mut() {
                if *t > rel {
                    *t += delta;
                }
            }
        }
        // The slot is held `delta` longer on this shard.
        self.shards[s].busy += delta;
        let held = self.kv_pages_held[j];
        self.kv_pages_held[j] = 0;
        if let Some(g) = self.shards[s].pool.kv_mut() {
            g.free(held);
            g.charge(reprefill as u64);
        }
        self.kv_suspend_until[j] = now + delta;
        let new_rel = self.kv_release_at[j] + delta;
        self.kv_release_at[j] = new_rel;
        self.push(new_rel.max(now), EvKind::ServerRelease(j));
        self.touch_shard(s);
        self.kv_preemptions += 1;
    }

    /// Hard-outage KV loss on shard `s`: every mid-decode stream whose
    /// KV lived there must re-prefill its full context. When a
    /// migration target admits, the stream *moves* — its source slot
    /// frees now and the target is booked through the §4.3 over-commit
    /// machinery until the stretched stream ends (the forced-migration
    /// variant of the paper's Eq. 5 buffer sizing) — otherwise it
    /// re-prefills in place on the draining source. Either way the
    /// rewrite stretches exactly one inter-token gap, so token
    /// conservation (no gaps, no duplicates, order) holds by
    /// construction. Admitted-but-unresolved streams are left to the
    /// connection-draining path (their prefill re-runs implicitly).
    fn kv_outage_failover(&mut self, s: usize, now: f64) {
        let live: Vec<usize> = self.kv_live[s].clone();
        for j in live {
            if !self.arena.resolved[j] || self.kv_release_done[j] {
                continue;
            }
            let (eligible, tbt_len) = match &self.records[j] {
                Some(r) => (r.winner == EndpointKind::Server && !r.migrated, r.tbts.len()),
                None => (false, 0),
            };
            let emitted = self.tokens_emitted(j, now);
            if !eligible || emitted == 0 || emitted > tbt_len {
                continue;
            }
            let reprefill =
                (self.server_tokens[j] as u64 + emitted as u64).min(u32::MAX as u64) as u32;
            let rate = self
                .fleet
                .batching
                .admission_tokens_per_sec()
                .expect("paged mode has an admission rate");
            // Fresh snapshot per victim: each placement is visible to
            // the next pick, spreading victims across survivors.
            let any_admitting = self.snapshot_views();
            let target = if any_admitting {
                pick_reprefill_target(&self.views, |t| {
                    self.shards[t].rtt + self.reprefill_queue_delay(t, None, false, 0.0)
                })
            } else {
                None
            };
            // The lost pages leave the source ledger either way.
            let held = self.kv_pages_held[j];
            self.kv_pages_held[j] = 0;
            if held > 0 {
                if let Some(g) = self.shards[s].pool.kv_mut() {
                    g.free(held);
                }
            }
            match target {
                Some(t) => {
                    // A tracked stream (iteration-level pricing) leaves
                    // the repricing set at the forced migration: its
                    // delivered record finalizes from the repriced
                    // timeline first, then the committed tail
                    // stretches like any other failover victim. No-op
                    // for untracked streams.
                    self.finalize_stream(j, s);
                    let delta = self.shards[t].rtt
                        + self.reprefill_queue_delay(t, None, false, 0.0)
                        + reprefill as f64 / rate;
                    let old_rel = self.kv_release_at[j];
                    let done = {
                        let rec = self.records[j].as_mut().expect("eligible implies a record");
                        rec.tbts[emitted - 1] += delta;
                        self.trace.requests[j].arrival
                            + rec.ttft
                            + rec.tbts.iter().sum::<f64>()
                    };
                    if done.is_finite() {
                        self.horizon = self.horizon.max(done);
                    }
                    // The source slot frees *now* instead of at the old
                    // release time: roll back the busy seconds it will
                    // not serve and retire the stream inline (the
                    // pending release event is superseded via
                    // `kv_release_done`).
                    self.kv_release_done[j] = true;
                    self.kv_live[s].retain(|&x| x != j);
                    let sample = self.arena.pre[j]
                        .server_sample
                        .expect("server users have a sample");
                    self.shards[s].work -= sample;
                    self.shards[s].busy -= (old_rel - now).max(0.0);
                    let next = self
                        .shards[s]
                        .pool
                        .release(&self.server_cancelled, &self.server_tokens);
                    self.touch_shard(s);
                    if let Some(n) = next {
                        self.on_server_admit(n, now);
                        self.try_resolve(n, now);
                    }
                    self.record_batch(s, now);
                    // Book the target through the §4.3 machinery: the
                    // stretched tail occupies it until the new end.
                    let real_slot = self.shards[t].pool.acquire_overflow();
                    let booked = (old_rel - now).max(0.0) + delta;
                    self.shards[t].work += booked;
                    self.shards[t].migrated_in += 1;
                    self.migration_targeted += 1;
                    if let Some(g) = self.shards[t].pool.kv_mut() {
                        let pages = g.pages_for(reprefill);
                        g.alloc(pages);
                        g.charge(reprefill as u64);
                        self.kv_mig_pages[j] = pages;
                    }
                    self.touch_shard(t);
                    self.migration_booking[j] = Some((t, real_slot, booked, now));
                    self.record_batch(t, now);
                    self.push((old_rel + delta).max(now), EvKind::MigrationRelease(j));
                    self.kv_suspend_until[j] = now + delta;
                }
                None => {
                    // Nowhere to go: re-prefill in place on the
                    // draining source, which keeps serving in-flight
                    // work under connection draining.
                    let delta = reprefill as f64 / rate;
                    if self.gen_times[j].is_empty() {
                        let done = {
                            let rec =
                                self.records[j].as_mut().expect("eligible implies a record");
                            rec.tbts[emitted - 1] += delta;
                            self.trace.requests[j].arrival
                                + rec.ttft
                                + rec.tbts.iter().sum::<f64>()
                        };
                        if done.is_finite() {
                            self.horizon = self.horizon.max(done);
                        }
                    } else {
                        // Tracked stream: the stall shifts the pending
                        // generation suffix; finalization at the
                        // (superseded, later) release delivers it.
                        let rel = now - self.trace.requests[j].arrival;
                        for t in self.gen_times[j].iter_mut() {
                            if *t > rel {
                                *t += delta;
                            }
                        }
                    }
                    self.shards[s].busy += delta;
                    if let Some(g) = self.shards[s].pool.kv_mut() {
                        g.charge(reprefill as u64);
                    }
                    self.kv_suspend_until[j] = now + delta;
                    let new_rel = self.kv_release_at[j] + delta;
                    self.kv_release_at[j] = new_rel;
                    self.push(new_rel.max(now), EvKind::ServerRelease(j));
                    self.touch_shard(s);
                }
            }
            self.kv_forced_reprefills += 1;
        }
    }

    /// Append a shard-count sample if the counts changed since the last
    /// one (evaluations that change nothing record nothing).
    fn record_timeline(&mut self, now: f64) {
        let warm = self
            .shards
            .iter()
            .filter(|s| s.phase == LifecyclePhase::Warm)
            .count();
        // "Provisioned" is capacity still being paid for — everything
        // short of Retired — so integrating the timeline agrees with
        // `shard_seconds` (a draining shard bills until its last stream
        // ends), and scale-out headroom uses the same count, so this
        // never exceeds `max_shards`.
        let provisioned = self
            .shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .count();
        if let Some(last) = self.timeline.last() {
            if last.warm == warm && last.provisioned == provisioned {
                return;
            }
        }
        self.timeline.push(ShardCountSample {
            time: now,
            warm,
            provisioned,
        });
    }

    /// Resolve the request once every resource it needs is granted or
    /// cancelled.
    fn try_resolve(&mut self, i: usize, now: f64) {
        let srv_cancelled = self.server_cancelled[i];
        let dev_cancelled = self.device_cancelled[i];
        let ready = !self.arena.resolved[i]
            && (!self.arena.needs_server[i] || self.arena.server_admit[i].is_some() || srv_cancelled)
            && (!self.arena.needs_device[i] || self.arena.device_grant[i].is_some() || dev_cancelled);
        if !ready {
            return;
        }
        let req = self.req(i);
        let shard = self.shard_of[i];
        self.arena.resolved[i] = true;
        let times = ResourceTimes {
            server_admit: if srv_cancelled {
                None
            } else {
                self.arena.server_admit[i]
            },
            device_grant: if dev_cancelled {
                f64::INFINITY
            } else {
                self.arena.device_grant[i].unwrap_or(f64::INFINITY)
            },
        };
        // `pre` is a local working copy (the RTT fold below must not
        // write back); the RNG stream stays in the arena and is resumed
        // in place — the old code cloned it here on every request.
        let mut pre = self.arena.pre[i];
        let device_grant = self.arena.device_grant[i];
        let server_was_admitted = self.arena.server_admit[i].is_some() && !srv_cancelled;
        let decode_slowdown = if self.reprice_active() && server_was_admitted {
            // Iteration-level pricing: price the stream at the batch it
            // actually starts decoding in — resolution can trail
            // admission when a device grant was pending, and repricing
            // cannot reach back before the record exists. Bit-identical
            // under a flat curve, where both prices are 1.0.
            let s = shard.expect("admitted requests are assigned");
            let live = self.batch_slowdown(s);
            self.arena.decode_slowdown[i] = live;
            live
        } else {
            self.arena.decode_slowdown[i]
        };
        self.resolved_count += 1;
        // The raw (pre-RTT-fold) prefill sample: the queued-ahead
        // correction in `reprefill_queue_delay` subtracts it when the
        // migration targets the stream's own shard.
        let own_sample = pre.server_sample.unwrap_or(0.0);
        // The shard's RTT offset folds into the pre-drawn prefill sample
        // so the perceived first token (and the §4.2 race) see the
        // shard's real latency. Work-estimate retirement: admissions stay
        // in the LeastWork signal until their ServerRelease event;
        // cancelled-in-queue entries (which never held a slot and get no
        // release) retire now.
        if let Some(s) = shard {
            let sample = pre.server_sample.expect("server users have a sample");
            if !server_was_admitted {
                self.shards[s].work -= sample;
                self.touch_shard(s);
            }
            pre.server_sample = Some(sample + self.shards[s].rtt);
        }
        // Shard-targeted §4.3 re-prefill: ask the balancer layer for the
        // least-work admitting shard (deterministic, no RNG consumed —
        // the fleet balancer stream is untouched), then fold that
        // shard's RTT *and* its predicted admission delay into the
        // endpoint the migration planner estimates and samples `t_m`
        // against. Only server-bound migrations (device-constrained
        // policies) have a shard to target; when every shard is
        // cold/draining the pick is None and the re-prefill falls back
        // to the source endpoint below (RTT inherited), counted in
        // `migration_fallbacks`.
        let (mig_pick, mig_ep, mig_slowdown) = if self.fleet.migration_targeting
            == MigrationTargeting::ShardTargeted
            && self.policy.migration
            && self.policy.constraint() == Some(Constraint::Device)
        {
            self.snapshot_views();
            // Least-work-with-estimate, the estimate being the shard's
            // RTT plus its predicted admission delay — priced in queued
            // prompt tokens under continuous batching.
            let pick = pick_reprefill_target(&self.views, |t| {
                self.shards[t].rtt
                    + self.reprefill_queue_delay(t, shard, server_was_admitted, own_sample)
            });
            let (ep, slow) = match pick {
                Some(t) => {
                    // Borrowed view of the target endpoint: the predicted
                    // queue delay combines with the shard's RTT offset in
                    // the same operand order as the historical
                    // `clone + extra_rtt += delay`, so the float result —
                    // and every downstream byte — is identical, without
                    // cloning a `ServerEndpoint` per migrated stream.
                    let delay =
                        self.reprefill_queue_delay(t, shard, server_was_admitted, own_sample);
                    let ep = MigrationServer::with_extra_rtt(
                        &self.server_endpoints[t],
                        self.server_endpoints[t].extra_rtt + delay,
                    );
                    // The migrated tail decodes in the target's batch:
                    // price it at the batch it would join (+1 for the
                    // joining stream itself).
                    let slow = match self.fleet.batching {
                        BatchingMode::Continuous(c) => {
                            c.curve.slowdown(self.shards[t].pool.in_use + 1)
                        }
                        BatchingMode::PagedKv(k) => {
                            k.curve.slowdown(self.shards[t].pool.in_use + 1)
                        }
                        BatchingMode::SlotLegacy => 1.0,
                    };
                    (ep, slow)
                }
                None => {
                    let ep = match shard {
                        Some(s) => MigrationServer::of(&self.server_endpoints[s]),
                        None => MigrationServer::of(&self.scenario.server),
                    };
                    (ep, 1.0)
                }
            };
            (pick, Some(ep), slow)
        } else {
            // Base-endpoint targeting books no shard, but under a
            // batched mode the migrated-in tail still decodes inside a
            // running batch — price it at the source shard's batch
            // (+1 for the joining tail), mirroring the shard-targeted
            // formula. `price_base_tails = false` pins the historical
            // unpriced (×1.0) tail for comparison; slot-legacy and
            // flat curves yield exactly 1.0 either way, so those runs
            // are byte-identical under both settings.
            let slow = if self.fleet.price_base_tails {
                match shard {
                    Some(s) => match self.fleet.batching {
                        BatchingMode::Continuous(c) => {
                            c.curve.slowdown(self.shards[s].pool.in_use + 1)
                        }
                        BatchingMode::PagedKv(k) => {
                            k.curve.slowdown(self.shards[s].pool.in_use + 1)
                        }
                        BatchingMode::SlotLegacy => 1.0,
                    },
                    None => 1.0,
                }
            } else {
                1.0
            };
            (None, None, slow)
        };
        // `mig_ep` borrows the endpoint table; remember the mode bit it
        // encodes before the borrow ends at the resolve call below.
        let targeting_active = mig_ep.is_some();
        // Every shard shares the base profile, so the source endpoint
        // only distinguishes shards through its RTT. The owning shard's
        // endpoint is used even when that shard is draining or retired:
        // under the legacy base-endpoint migration fallback the victim's
        // RTT offset must still be inherited (dropping it silently
        // undercounted migration latency — see the engine regression
        // test). Static fleets are always Warm, preserving byte parity.
        let server_ep = match shard {
            Some(s) => &self.server_endpoints[s],
            None => &self.scenario.server,
        };
        let batch = BatchCtx {
            decode_slowdown,
            migration_decode_slowdown: mig_slowdown,
        };
        let resolved = resolve_request(
            req,
            &pre,
            self.policy,
            server_ep,
            &self.scenario.device,
            mig_ep,
            &self.planner,
            &self.scenario.cfg,
            times,
            batch,
            &mut self.arena.rng[i],
        );

        // Iteration-level pricing tracks resolved server winners still
        // decoding in their shard's batch: the record stays provisional
        // until the release event finalizes it from the (re-stamped)
        // generation timeline. Migrated streams' tails were committed
        // at handoff pricing and are never repriced.
        let track = self.reprice_active()
            && server_was_admitted
            && resolved.record.winner == EndpointKind::Server
            && !resolved.record.migrated
            && !resolved.gen_rel.is_empty();

        // Completion horizon: last delivered token of this stream.
        // Tracked streams defer this to finalization — repricing may
        // still move their completion either way.
        if !track {
            let done =
                req.arrival + resolved.record.ttft + resolved.record.tbts.iter().sum::<f64>();
            if done.is_finite() {
                self.horizon = self.horizon.max(done);
            }
        }

        // Server slot accounting + release (on the owning shard).
        if server_was_admitted {
            let s = shard.expect("admitted requests are assigned");
            let admit = times.server_admit.expect("admitted");
            let release = resolved.server_release.unwrap_or(admit).max(admit);
            self.shards[s].busy += release - admit;
            // Every admission gets a release event — also on unlimited
            // pools, where it frees no slot but retires the in-service
            // `in_use`/work signals the balancers read. Release never
            // exceeds the stream's own completion horizon, so replay
            // horizons are unchanged. Paged mode and iteration-level
            // pricing stamp the release time so later preemption,
            // failover, or repricing can supersede it (the
            // stale-release guard keys on this exact timestamp).
            let at = release.max(now);
            if self.release_guard_active() {
                self.kv_release_at[i] = at;
            }
            self.push(at, EvKind::ServerRelease(i));
        }
        // (An entry cancelled while still queued holds no slot; the
        // lazily-skipped queue entry frees nothing.)

        // Device accounting + release.
        if let (Some(grant), false) = (device_grant, dev_cancelled) {
            let until = resolved.device_busy_until.unwrap_or(grant).max(grant);
            self.device_busy += until - grant;
            if self.fleet.device_queueing {
                self.push(until.max(now), EvKind::DeviceRelease);
            }
        }

        // Shard-targeted migration booking: the migrated stream joins
        // its target shard's slot pool (a real slot when one is spare,
        // batch-join over-commit otherwise) and carries its sampled
        // `t_m` as outstanding work until the stream ends — so balancers
        // and the autoscaler see migrated-in load, and a draining target
        // cannot retire from under a stream migrating onto it. Booked at
        // resolve time (slightly before the handoff instant) precisely
        // to pin the target alive through the handoff.
        if let Some(info) = resolved.migration {
            if info.target == EndpointKind::Server {
                match mig_pick {
                    Some(t) => {
                        let real_slot = self.shards[t].pool.acquire_overflow();
                        self.shards[t].work += info.t_m;
                        self.shards[t].migrated_in += 1;
                        // Paged KV: the migrated-in stream's re-prefill
                        // occupies pages on the target for its lifetime
                        // (freed at `MigrationRelease`).
                        let len = self.prompt_tokens[i];
                        if let Some(g) = self.shards[t].pool.kv_mut() {
                            let pages = g.pages_for(len);
                            g.alloc(pages);
                            self.kv_mig_pages[i] = pages;
                        }
                        self.touch_shard(t);
                        self.migration_booking[i] = Some((t, real_slot, info.t_m, now));
                        self.migration_targeted += 1;
                        self.record_batch(t, now);
                        self.push(info.end_abs.max(now), EvKind::MigrationRelease(i));
                    }
                    None if targeting_active => self.migration_fallbacks += 1,
                    // Legacy base-endpoint targeting: no shard is
                    // involved, nothing to book.
                    None => {}
                }
            }
        }

        if track {
            let s = shard.expect("admitted requests are assigned");
            self.gen_times[i] = resolved.gen_rel;
            self.decode_live[s].push(i);
        }
        self.records[i] = Some(resolved.record);
    }
}

/// Run a trace through the fleet loop. Requests must arrive in
/// nondecreasing time order (the trace generators guarantee this); ties
/// are broken in trace order.
///
/// # RNG-stream invariant
///
/// Per-request RNG streams are forked from `SimConfig.seed` **in trace
/// order**, tagged by `Request.id` — request `k`'s latency draws depend
/// on both its position and its id, never on event interleaving. Any
/// transformation that reorders a trace (randomized replay of session
/// traces, overlaying several traces) must therefore keep requests
/// arrival-sorted and reassign ids in the new order; use
/// [`crate::trace::generator::shuffle_payloads`] /
/// [`crate::trace::generator::interleave`], which preserve the
/// invariant by construction.
pub fn run_fleet(
    scenario: &Scenario,
    trace: &Trace,
    policy: &Policy,
    fleet: &FleetConfig,
) -> FleetOutcome {
    let n = trace.len();
    let shard_count = fleet.shards.max(1);
    // A zero-slot pool could never admit anyone; normalize once so the
    // pools and the reported LoadReport.server_slots always agree. RTT
    // offsets are padded/truncated to the shard count; autoscale bands
    // are clamped sane.
    let mut rtts = fleet.shard_rtts.clone();
    rtts.resize(shard_count, 0.0);
    // Faults are padded/truncated to the *static* shard count: shards
    // the autoscaler provisions later are always healthy, as documented.
    let mut faults = fleet.shard_faults.clone();
    faults.resize(shard_count, None);
    let batching = fleet.batching.normalized();
    // Under a gated batching mode (continuous or paged KV) the slot cap
    // is gone: the token budget / page ledger gates admission and the
    // batch (not a slot count) bounds concurrency, so pools — and the
    // reported capacity — are uncapped.
    let pool_cap = if batching.batched() {
        None
    } else {
        fleet.server_slots.map(|s| s.max(1))
    };
    // Setup-time clones only: the padded RTT table is *moved* into the
    // normalized config (the run phase borrows it back), and the outage
    // schedule is cloned exactly once here — the event loop reads both
    // in place (this PR's allocation sweep removed the per-run-phase
    // re-clones).
    let fleet = FleetConfig {
        server_slots: pool_cap,
        device_queueing: fleet.device_queueing,
        shards: shard_count,
        balancer: fleet.balancer,
        shard_rtts: rtts,
        autoscale: fleet.autoscale.map(|a| a.normalized()),
        migration_targeting: fleet.migration_targeting,
        shard_faults: faults,
        outages: fleet.outages.clone(),
        batching,
        pricing: fleet.pricing,
        price_base_tails: fleet.price_base_tails,
        event_queue: fleet.event_queue,
    };
    let server_endpoints = ServerEndpoint::shard_fleet(&scenario.server, &fleet.shard_rtts);
    // Initial shards are created warm at the first arrival (created_at
    // is stamped in `run`).
    let shards: Vec<ShardState> = fleet
        .shard_rtts
        .iter()
        .map(|&rtt| {
            ShardState::new(
                Pool::new(pool_cap).with_gate_kind(make_gate(&batching)),
                rtt,
                LifecyclePhase::Warm,
                0.0,
                0.0,
            )
        })
        .collect();
    let device_pool = Pool::new(if fleet.device_queueing { Some(1) } else { None });
    let prompt_tokens: Vec<u32> = trace.requests.iter().map(|r| r.prompt_len).collect();
    // `AutoscaleConfig` is Copy, so the normalized config can live both
    // in `fleet` (for Debug/consumers) and as the loop's working copy.
    let autoscale = fleet.autoscale;
    let scaler = autoscale.as_ref().and_then(|a| a.kind.build());
    // The deterministic scan balancers get an incrementally maintained
    // argmin index (built even at K=1 so autoscaled growth picks it up;
    // the K=1 fast path bypasses it until the fleet actually grows).
    let shard_index = match fleet.balancer {
        BalancerKind::JoinShortestQueue | BalancerKind::LeastWork => {
            Some(ShardIndex::new(shard_count))
        }
        _ => None,
    };
    let queue = EventQueue::new(fleet.event_queue);
    let sim = FleetSim {
        scenario,
        trace,
        policy,
        planner: MigrationPlanner::new(scenario.cfg.migration, scenario.costs),
        balancer: fleet.balancer.build(),
        // Disjoint from the root request-stream RNG by construction (a
        // different seed expansion), so balancer draws never perturb
        // request trajectories.
        brng: Rng::new(scenario.cfg.seed ^ 0xBA1A_7CE5_0C4A_11CE),
        // The autoscaler's own stream, disjoint from both of the above.
        arng: Rng::new(scenario.cfg.seed ^ 0xA5CA_1E05_EED0_0001),
        // The fault-injection stream (disjoint again); never drawn when
        // no `ShardFault` is configured.
        frng: Rng::new(scenario.cfg.seed ^ 0xFA17_1217_EC7E_D001),
        autoscale,
        scaler,
        fleet,
        server_endpoints,
        queue,
        arena: StreamArena::new(n),
        shard_index,
        server_cancelled: vec![false; n],
        device_cancelled: vec![false; n],
        shards,
        shard_of: vec![None; n],
        views: Vec::new(),
        device_pool,
        records: (0..n).map(|_| None).collect(),
        device_delays: Vec::new(),
        device_busy: 0.0,
        horizon: 0.0,
        resolved_count: 0,
        scale_events: Vec::new(),
        timeline: Vec::new(),
        cold_start_seconds: 0.0,
        migration_booking: (0..n).map(|_| None).collect(),
        migration_targeted: 0,
        migration_fallbacks: 0,
        outage_requeues: 0,
        server_tokens: prompt_tokens.clone(),
        prompt_tokens,
        pool_cap,
        batch_samples: Vec::new(),
        kv_live: vec![Vec::new(); shard_count],
        kv_pages_held: vec![0; n],
        kv_suspend_until: vec![0.0; n],
        kv_release_at: vec![0.0; n],
        kv_release_done: vec![false; n],
        kv_mig_pages: vec![0; n],
        kv_preemptions: 0,
        kv_forced_reprefills: 0,
        gen_times: vec![Vec::new(); n],
        decode_live: vec![Vec::new(); shard_count],
        reprice_events: 0,
        reprice_stretch_seconds: 0.0,
        reprice_shrink_seconds: 0.0,
        t0: 0.0,
    };
    sim.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyKind;
    use crate::cost::unified::Constraint;
    use crate::profiles::{DeviceProfile, ServerProfile};
    use crate::sim::engine::SimConfig;
    use crate::trace::generator::{Arrival, WorkloadSpec};

    fn scenario(seed: u64) -> Scenario {
        Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    fn trace_at_gap(n: usize, gap: f64, seed: u64) -> Trace {
        WorkloadSpec {
            arrival: Arrival::Fixed { gap },
            ..WorkloadSpec::alpaca(n)
        }
        .generate(seed)
    }

    #[test]
    fn unlimited_fleet_is_byte_identical_to_replay() {
        let sc = scenario(21);
        let trace = WorkloadSpec::alpaca(300).generate(5);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        let legacy = sc.run(&trace, &policy);
        let fleet = run_fleet(&sc, &trace, &policy, &FleetConfig::replay(false));
        assert_eq!(legacy, fleet.records);
    }

    #[test]
    fn generous_capacity_matches_replay_closely() {
        // With capacity far above offered load the admission queue never
        // forms and the bounded fleet reproduces the replay results.
        let sc = scenario(22);
        let trace = trace_at_gap(200, 60.0, 6);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let replay = sc.run_report(&trace, &policy);
        let fleet = sc.run_fleet_report(
            &trace,
            &policy,
            &FleetConfig {
                server_slots: Some(64),
                device_queueing: false,
                ..FleetConfig::replay(false)
            },
        );
        let dm = (fleet.qoe.ttft.mean - replay.ttft.mean).abs() / replay.ttft.mean;
        let dp = (fleet.qoe.ttft.p99 - replay.ttft.p99).abs() / replay.ttft.p99;
        assert!(dm < 0.02, "mean TTFT drift {dm:.4}");
        assert!(dp < 0.02, "p99 TTFT drift {dp:.4}");
        assert!(fleet.load.server_queue_delay.max < 1e-9);
    }

    // (Queue-delay monotonicity in load is asserted once, end-to-end, in
    // tests/integration.rs::fleet_queue_delay_monotone_in_load.)

    #[test]
    fn server_utilization_bounded_by_one() {
        let sc = scenario(24);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let trace = trace_at_gap(120, 0.5, 8);
        let out = sc.run_fleet_report(&trace, &policy, &FleetConfig::bounded(2));
        let util = out.load.server_utilization().unwrap();
        assert!(util > 0.5, "overloaded pool should be busy, util={util:.3}");
        assert!(util <= 1.0 + 1e-9, "util {util:.3} > 1");
        assert!(out.load.mean_server_concurrency() <= 2.0 + 1e-9);
    }

    #[test]
    fn device_fallback_bounds_overloaded_server() {
        // A slow server (DeepSeek: ~1.25 s TTFT + ~30 tok/s decode) with
        // one admission slot at ~1.3× overload queues without bound under
        // ServerOnly. Racing both endpoints lets the single-flight device
        // absorb the traffic (short outputs keep its service time under
        // the arrival gap), so the first token stays bounded AND winning
        // devices cancel the queued server entries, shedding server load.
        let sc = Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed: 25,
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed { gap: 1.4 },
            prompt: crate::trace::generator::LengthModel::new(20.0, 0.5, 4, 128),
            output: crate::trace::generator::LengthModel::new(16.0, 0.3, 4, 32),
            ..WorkloadSpec::alpaca(120)
        };
        let trace = spec.generate(9);
        let fleet_cfg = FleetConfig {
            server_slots: Some(1),
            ..FleetConfig::replay(true)
        };
        let server_only = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let race = Policy::simple(PolicyKind::StochS, 1.0, false);
        let rs = sc.run_fleet_report(&trace, &server_only, &fleet_cfg);
        let rr = sc.run_fleet_report(&trace, &race, &fleet_cfg);
        assert!(
            rs.qoe.ttft.p99 > 3.0 * rr.qoe.ttft.p99,
            "device fallback should bound p99: ServerOnly {:.2}s vs race {:.2}s",
            rs.qoe.ttft.p99,
            rr.qoe.ttft.p99
        );
        assert!(
            rr.qoe.ttft.p99 < 10.0,
            "raced p99 should stay bounded, got {:.2}s",
            rr.qoe.ttft.p99
        );
    }

    #[test]
    fn fleet_run_is_deterministic() {
        let sc = scenario(26);
        let trace = trace_at_gap(100, 1.0, 10);
        let policy = Policy::simple(PolicyKind::StochS, 0.8, false);
        let cfg = FleetConfig::bounded(2);
        let a = run_fleet(&sc, &trace, &policy, &cfg);
        let b = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(a.records, b.records);
    }

    // -----------------------------------------------------------------
    // Sharded fleet
    // -----------------------------------------------------------------

    /// Single-pool parity: a K=1 shard "fleet" must reproduce the PR-1
    /// single-pool records byte-for-byte under every balancer (the
    /// balancer is bypassed at K=1 and its RNG stream never drawn).
    #[test]
    fn k1_shard_matches_single_pool_exactly() {
        let sc = scenario(27);
        let trace = trace_at_gap(150, 0.8, 11);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        let single = run_fleet(&sc, &trace, &policy, &FleetConfig::bounded(2));
        for kind in BalancerKind::all() {
            let cfg = FleetConfig::sharded(1, 2, kind);
            let sharded = run_fleet(&sc, &trace, &policy, &cfg);
            assert_eq!(
                single.records, sharded.records,
                "K=1 {kind} diverged from the single-pool fleet"
            );
            assert_eq!(sharded.load.shards.len(), 1);
        }
    }

    /// K shards with S slots each behave like capacity K·S: total
    /// admissions conserved, every request lands on exactly one shard.
    #[test]
    fn shards_conserve_admissions() {
        let sc = scenario(28);
        let trace = trace_at_gap(200, 0.5, 12);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        for kind in BalancerKind::all() {
            let out = run_fleet(&sc, &trace, &policy, &FleetConfig::sharded(4, 1, kind));
            assert_eq!(out.records.len(), 200);
            assert_eq!(out.load.shards.len(), 4);
            let admitted: usize = out.load.shards.iter().map(|s| s.admitted).sum();
            assert_eq!(admitted, 200, "{kind}: every request admits exactly once");
            assert_eq!(out.load.total_server_slots(), Some(4));
            let shard_busy: f64 = out.load.shards.iter().map(|s| s.busy_seconds).sum();
            assert!(
                (shard_busy - out.load.server_busy_seconds).abs() < 1e-9,
                "{kind}: busy-seconds must decompose per shard"
            );
            let util = out.load.server_utilization().unwrap();
            assert!(util <= 1.0 + 1e-9, "{kind}: util {util:.3} > 1");
        }
    }

    /// Round-robin spreads a server-only trace evenly across shards.
    #[test]
    fn round_robin_spreads_evenly() {
        let sc = scenario(29);
        let trace = trace_at_gap(120, 2.0, 13);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let out = run_fleet(
            &sc,
            &trace,
            &policy,
            &FleetConfig::sharded(4, 2, BalancerKind::RoundRobin),
        );
        for s in &out.load.shards {
            assert_eq!(s.admitted, 30, "RR must deal 120 requests 30/30/30/30");
        }
    }

    /// The power-of-two balancer draws from a seeded fleet-level stream:
    /// identical runs are byte-identical, and the per-shard assignment
    /// depends only on the seed.
    #[test]
    fn power_of_two_is_deterministic_under_fixed_seed() {
        let sc = scenario(30);
        let trace = trace_at_gap(150, 0.6, 14);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::sharded(4, 1, BalancerKind::PowerOfTwoChoices);
        let a = run_fleet(&sc, &trace, &policy, &cfg);
        let b = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(a.records, b.records);
        let counts = |o: &FleetOutcome| -> Vec<usize> {
            o.load.shards.iter().map(|s| s.admitted).collect()
        };
        assert_eq!(counts(&a), counts(&b), "shard assignment must reproduce");
        // A different scenario seed re-seeds the balancer stream too.
        let c = run_fleet(&scenario(31), &trace, &policy, &cfg);
        assert_ne!(a.records, c.records);
    }

    /// Heterogeneous shard RTTs surface in perceived TTFT: a fleet whose
    /// shards all carry +Δ RTT shifts every server-won TTFT by ≥ Δ
    /// relative to the homogeneous fleet.
    #[test]
    fn shard_rtt_offsets_shift_ttft() {
        let sc = scenario(32);
        let trace = trace_at_gap(80, 30.0, 15);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let base = run_fleet(
            &sc,
            &trace,
            &policy,
            &FleetConfig::sharded(2, 4, BalancerKind::RoundRobin),
        );
        let slow = run_fleet(
            &sc,
            &trace,
            &policy,
            &FleetConfig::sharded(2, 4, BalancerKind::RoundRobin)
                .with_shard_rtts(vec![0.25, 0.25]),
        );
        for (b, s) in base.records.iter().zip(&slow.records) {
            assert!(
                (s.ttft - b.ttft - 0.25).abs() < 1e-9,
                "uniform +0.25s shard RTT must shift TTFT: {} vs {}",
                s.ttft,
                b.ttft
            );
        }
    }

    /// JSQ keeps shard queues balanced where round-robin lets them
    /// diverge: on the same trace, mean queue delay under JSQ must not
    /// exceed round-robin's, and the imbalance summary must be sane.
    #[test]
    fn jsq_queue_delay_not_worse_than_round_robin() {
        let sc = scenario(33);
        let trace = trace_at_gap(300, 0.4, 16);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let run = |kind| {
            run_fleet(&sc, &trace, &policy, &FleetConfig::sharded(4, 1, kind)).load
        };
        let rr = run(BalancerKind::RoundRobin);
        let jsq = run(BalancerKind::JoinShortestQueue);
        assert!(
            jsq.server_queue_delay.mean <= rr.server_queue_delay.mean * 1.02,
            "JSQ mean queue delay {:.3} should not exceed RR {:.3}",
            jsq.server_queue_delay.mean,
            rr.server_queue_delay.mean
        );
        for load in [&rr, &jsq] {
            let imb = load.shard_imbalance().unwrap();
            assert!(imb >= 1.0 - 1e-9 && imb.is_finite(), "imbalance {imb}");
        }
    }

    // -----------------------------------------------------------------
    // Autoscaling
    // -----------------------------------------------------------------

    use crate::sim::autoscaler::{AutoscalerKind, ColdStartSpec, ReactiveConfig};

    /// An aggressive reactive config for tests: act on the first
    /// overloaded/idle evaluation, add up to `max_step` shards at once.
    fn eager_reactive(min: usize, max: usize, cold: f64) -> AutoscaleConfig {
        AutoscaleConfig {
            kind: AutoscalerKind::Reactive(ReactiveConfig {
                scale_out_per_shard: 2.0,
                scale_in_per_shard: 0.5,
                sustain: 1,
                cooldown: 0.0,
                max_step: max,
            }),
            eval_interval: 0.5,
            min_shards: min,
            max_shards: max,
            cold_start: ColdStartSpec::Fixed(cold),
        }
    }

    /// A burst trace: `n_burst` arrivals every 0.25 s, then a calm tail
    /// that gives the autoscaler room to drain back down.
    fn burst_then_calm(n_burst: usize, n_calm: usize, seed: u64) -> Trace {
        let mut t = WorkloadSpec::alpaca(n_burst + n_calm).generate(seed);
        let mut now = 0.0;
        for (i, r) in t.requests.iter_mut().enumerate() {
            r.arrival = now;
            now += if i < n_burst { 0.25 } else { 3.0 };
        }
        t
    }

    /// Uniform token weights for Pool unit tests (slot pools ignore the
    /// values; the queued-token counter still tracks them).
    fn toks(n: usize) -> Vec<u32> {
        vec![10; n]
    }

    #[test]
    fn frozen_pool_queues_until_unfrozen() {
        let mut p = Pool::new_frozen(Some(2));
        let cancelled = vec![false; 4];
        let tokens = toks(4);
        // Everything queues while frozen, even with spare capacity.
        assert!(!p.acquire(0, 10));
        assert!(!p.acquire(1, 10));
        assert!(!p.acquire(2, 10));
        assert_eq!(p.in_use, 0);
        assert_eq!(p.live_queued(), 3);
        assert_eq!(p.queued_prompt_tokens(), 30);
        assert_eq!(
            p.try_admit(&cancelled, &tokens),
            None,
            "frozen pools admit nothing"
        );
        // Unfreeze: admissions drain in FIFO order up to the cap.
        p.frozen = false;
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(0));
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(1));
        assert_eq!(p.try_admit(&cancelled, &tokens), None, "cap reached");
        assert_eq!(p.in_use, 2);
        assert_eq!(p.live_queued(), 1);
        assert_eq!(p.queued_prompt_tokens(), 10);
        // New acquires behave like a normal bounded pool now.
        assert!(!p.acquire(3, 10));
        let next = p.release(&cancelled, &tokens);
        assert_eq!(next, Some(2));
        assert_eq!(p.underflows, 0);
    }

    /// Tentpole parity: attaching an `AutoscalerKind::None` config is
    /// byte-identical to the plain static fleet — no evaluation events
    /// are scheduled, so even the event-sequence numbering matches.
    #[test]
    fn autoscaler_none_matches_static_fleet() {
        let sc = scenario(34);
        let trace = trace_at_gap(150, 0.6, 17);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        let static_cfg = FleetConfig::sharded(3, 1, BalancerKind::JoinShortestQueue);
        let auto_cfg = static_cfg.clone().with_autoscale(AutoscaleConfig::fixed());
        let a = run_fleet(&sc, &trace, &policy, &static_cfg);
        let b = run_fleet(&sc, &trace, &policy, &auto_cfg);
        assert_eq!(a.records, b.records);
        assert_eq!(format!("{:?}", a.load), format!("{:?}", b.load));
        assert!(a.load.scale_events.is_empty());
        assert_eq!(a.load.shard_timeline.len(), 1, "static fleets record one sample");
        assert!((a.load.shard_seconds - 3.0 * a.load.horizon).abs() < 1e-9);
    }

    /// Reactive autoscaling under a burst: the fleet scales out (paying
    /// real cold-start seconds), every request still resolves, queue
    /// delays beat the static-small fleet, and the calm tail drains the
    /// extra shards back down (drain → retire).
    #[test]
    fn reactive_autoscaler_scales_out_and_drains_back() {
        let sc = scenario(35);
        let trace = burst_then_calm(150, 30, 18);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let static_small = FleetConfig::sharded(1, 1, BalancerKind::JoinShortestQueue);
        let auto_cfg = static_small.clone().with_autoscale(eager_reactive(1, 4, 1.0));
        let small = run_fleet(&sc, &trace, &policy, &static_small);
        let auto = run_fleet(&sc, &trace, &policy, &auto_cfg);

        // Liveness: every request resolves even with shards appearing
        // and retiring mid-run.
        assert_eq!(auto.records.len(), trace.len());
        // The burst forces scale-out, and every provisioned shard warms.
        let outs = auto.load.scale_out_count();
        assert!(outs >= 1, "burst must trigger scale-out");
        let warms = auto
            .load
            .scale_events
            .iter()
            .filter(|e| e.kind == ScaleEventKind::WarmUp)
            .count();
        assert_eq!(warms, outs, "every cold shard must warm exactly once");
        assert!(auto.load.cold_start_seconds > 0.0);
        assert!(auto.load.peak_warm_shards() > 1);
        assert!(auto.load.peak_warm_shards() <= 4, "max_shards must cap scale-out");
        // Scaling out must beat the static-small fleet's queueing.
        assert!(
            auto.load.server_queue_delay.p99 < small.load.server_queue_delay.p99,
            "autoscaled p99 queue {:.2}s must beat static K=1 {:.2}s",
            auto.load.server_queue_delay.p99,
            small.load.server_queue_delay.p99
        );
        // The calm tail drains the fleet back down: drains and retires
        // happen, and the run costs less than peak-sized provisioning.
        let drains = auto
            .load
            .scale_events
            .iter()
            .filter(|e| e.kind == ScaleEventKind::DrainStart)
            .count();
        let retires = auto
            .load
            .scale_events
            .iter()
            .filter(|e| e.kind == ScaleEventKind::Retire)
            .count();
        assert!(drains >= 1, "calm tail must trigger scale-in");
        assert!(retires >= 1, "drained shards must retire");
        assert!(retires <= drains);
        assert!(
            auto.load.shard_seconds < auto.load.peak_warm_shards() as f64 * auto.load.horizon,
            "draining must cost less than peak-sized static provisioning"
        );
        // Timeline sanity: starts at the initial K, never exceeds the cap.
        let tl = &auto.load.shard_timeline;
        assert!(tl.len() >= 3, "timeline must record the scaling story");
        assert_eq!(tl[0].warm, 1);
        assert!(tl.iter().all(|s| s.provisioned <= 4 && s.warm <= s.provisioned));
    }

    /// Autoscaled runs are bit-reproducible: same seed, same topology
    /// trajectory, same records.
    #[test]
    fn autoscaled_run_is_deterministic() {
        let sc = scenario(36);
        let trace = burst_then_calm(100, 20, 19);
        let policy = Policy::simple(PolicyKind::StochS, 0.8, false);
        let cfg = FleetConfig::sharded(1, 1, BalancerKind::PowerOfTwoChoices)
            .with_autoscale(eager_reactive(1, 3, 0.8));
        let a = run_fleet(&sc, &trace, &policy, &cfg);
        let b = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(a.records, b.records);
        assert_eq!(format!("{:?}", a.load), format!("{:?}", b.load));
    }

    // -----------------------------------------------------------------
    // Migration-aware shard targeting + failure injection
    // -----------------------------------------------------------------

    use crate::metrics::ScaleEventKind as Sek;

    /// A device-constrained scenario whose server is slow enough that the
    /// device wins the race (so §4.3 migrates decode *onto* the server
    /// fleet).
    fn device_constrained_scenario(seed: u64) -> Scenario {
        Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Device,
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    fn overflow_pool_books_real_slots_then_batch_joins() {
        let mut p = Pool::new(Some(2));
        let cancelled = vec![false; 4];
        let tokens = toks(4);
        assert!(p.acquire(0, 10));
        // One spare slot: the first migrated-in stream takes a real one.
        assert!(p.acquire_overflow(), "spare capacity ⇒ real slot");
        assert_eq!(p.in_use, 2);
        assert_eq!(p.over_commit, 0);
        // Full: the next joins the batch over-capacity.
        assert!(!p.acquire_overflow(), "full pool ⇒ batch join");
        assert_eq!(p.in_use, 3);
        assert_eq!(p.over_commit, 1);
        assert_eq!(p.peak_in_use, 3);
        // A queued arrival waits behind the real slots.
        assert!(!p.acquire(1, 10));
        // Over-commit release while still at/over cap frees no slot: the
        // queue stays put.
        assert_eq!(p.release_overflow(&cancelled, &tokens), None);
        assert_eq!(p.in_use, 2);
        assert_eq!(p.live_queued(), 1);
        // Real-slot release transfers the unit to the queued entry.
        assert_eq!(p.release(&cancelled, &tokens), Some(1));
        assert_eq!(p.in_use, 2);
        // Unlimited pools always report a real slot.
        let mut u = Pool::new(None);
        assert!(u.acquire_overflow());
    }

    /// Liveness regression: an over-commit booking whose real slots
    /// drained away underneath it becomes load-bearing — releasing it
    /// must admit the queue, or the queued entry would wait forever (no
    /// later release event exists on the shard).
    #[test]
    fn overflow_release_admits_queue_when_load_bearing() {
        let mut p = Pool::new(Some(1));
        let cancelled = vec![false; 3];
        let tokens = toks(3);
        assert!(p.acquire(0, 10)); // real holder
        assert!(!p.acquire_overflow(), "full ⇒ batch join");
        assert_eq!(p.in_use, 2);
        // The real holder leaves with an empty queue: plain decrement.
        assert_eq!(p.release(&cancelled, &tokens), None);
        assert_eq!(p.in_use, 1);
        // A new arrival queues behind the (now load-bearing) over-commit.
        assert!(!p.acquire(1, 10));
        // Releasing the over-commit must hand the freed capacity over.
        assert_eq!(p.release_overflow(&cancelled, &tokens), Some(1));
        assert_eq!(p.in_use, 1);
        assert_eq!(p.live_queued(), 0);
        assert_eq!(p.underflows, 0);
    }

    /// Bugfix regression (this PR): a double over-commit release used to
    /// `saturating_sub` its way into freeing a slot a real holder still
    /// occupied — admitting the queue twice off one booking and leaking
    /// capacity for the rest of the run. Now the spurious release is
    /// refused and counted.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "over-commit release"))]
    fn double_migration_release_cannot_free_a_slot_twice() {
        let mut p = Pool::new(Some(1));
        let cancelled = vec![false; 3];
        let tokens = toks(3);
        assert!(p.acquire(0, 10)); // real holder, stays in service
        assert!(!p.acquire_overflow(), "full ⇒ batch join");
        assert!(!p.acquire(1, 10), "arrival queues behind the real slot");
        // Legitimate over-commit release: no spare capacity yet.
        assert_eq!(p.release_overflow(&cancelled, &tokens), None);
        assert_eq!(p.in_use, 1);
        // The DOUBLE release (a bug upstream): in release builds it must
        // not admit the queued entry — request 0 still holds the only
        // slot — and must be recorded; in debug builds it asserts.
        assert_eq!(p.release_overflow(&cancelled, &tokens), None);
        assert_eq!(p.underflows, 1, "double release must be counted");
        assert_eq!(p.in_use, 1, "the real holder's unit must survive");
        assert_eq!(p.live_queued(), 1, "the queue must not be admitted");
        // The real holder's own release still works normally.
        assert_eq!(p.release(&cancelled, &tokens), Some(1));
    }

    /// Bugfix regression (this PR): a plain double release on an empty
    /// pool is counted instead of silently clamped.
    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "nothing in use"))]
    fn double_release_is_counted_not_masked() {
        let mut p = Pool::new(Some(2));
        let cancelled = vec![false; 1];
        let tokens = toks(1);
        assert!(p.acquire(0, 10));
        assert_eq!(p.release(&cancelled, &tokens), None);
        assert_eq!(p.underflows, 0);
        assert_eq!(p.release(&cancelled, &tokens), None); // the bug
        assert_eq!(p.underflows, 1);
        assert_eq!(p.in_use, 0, "no wraparound, no phantom capacity");
    }

    #[test]
    fn drain_queue_returns_live_entries_in_fifo_order() {
        let mut p = Pool::new(Some(1));
        let mut cancelled = vec![false; 5];
        assert!(p.acquire(0, 10));
        for j in 1..5 {
            assert!(!p.acquire(j, 10));
        }
        cancelled[2] = true;
        p.cancel_queued(10);
        assert_eq!(p.drain_queue(&cancelled), vec![1, 3, 4]);
        assert_eq!(p.live_queued(), 0);
        assert_eq!(p.queued_prompt_tokens(), 0);
        assert_eq!(p.in_use, 1, "in-flight admissions are untouched");
    }

    // -----------------------------------------------------------------
    // Continuous batching: the token-gated pool
    // -----------------------------------------------------------------

    fn batch_pool(budget: u32, max_batch: Option<usize>) -> Pool {
        let cfg = ContinuousBatchConfig {
            prefill_tokens_per_tick: budget,
            tick_interval: 0.25,
            max_batch,
            curve: crate::sim::batching::BatchLatencyCurve::Flat,
        };
        Pool::new(None).with_gate(Some(BatchGate::new(&cfg)))
    }

    #[test]
    fn token_gate_admits_until_budget_exhausts_then_queues() {
        let mut p = batch_pool(25, None);
        let cancelled = vec![false; 5];
        let tokens = vec![10, 10, 10, 10, 10];
        assert!(p.acquire(0, 10));
        assert!(p.acquire(1, 10));
        // 5 tokens left < 10: the third arrival queues.
        assert!(!p.acquire(2, 10));
        assert_eq!(p.in_use, 2);
        assert_eq!(p.live_queued(), 1);
        assert_eq!(p.queued_prompt_tokens(), 10);
        // A release frees batch headroom but NOT budget: no slot
        // transfer happens under the gate.
        assert_eq!(p.release(&cancelled, &tokens), None);
        assert_eq!(p.in_use, 1);
        assert_eq!(p.live_queued(), 1, "budget-gated: release transfers nothing");
        // The tick replenishes the budget and the queue drains FIFO.
        p.tick();
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(2));
        assert_eq!(p.try_admit(&cancelled, &tokens), None, "queue empty");
        assert_eq!(p.in_use, 2);
        let (admitted, capacity) = p.token_totals();
        assert_eq!(admitted, 30);
        assert_eq!(capacity, 50, "initial allotment + one tick");
        // A busy tick (budget partially consumed) accrues capacity…
        p.tick();
        assert_eq!(p.token_totals().1, 75);
        // …but an idle tick — full budget, empty queue — does not
        // (review fix: idle tails must not dilute token utilization).
        p.tick();
        assert_eq!(p.token_totals().1, 75, "idle ticks offer no capacity");
    }

    #[test]
    fn token_gate_oversized_prompt_takes_a_fresh_tick() {
        let mut p = batch_pool(32, None);
        let cancelled = vec![false; 3];
        let tokens = vec![100, 8, 8];
        // An oversized prompt admits against a fresh budget, consuming
        // all of it (no chunked prefill yet) — it cannot starve.
        assert!(p.acquire(0, 100));
        assert_eq!(p.in_use, 1);
        // The emptied budget blocks even small prompts until the tick.
        assert!(!p.acquire(1, 8));
        p.tick();
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(1));
        // A partially-consumed budget does NOT admit oversized prompts
        // (only a fresh one does): head-of-line waits for its tick.
        assert!(!p.acquire(2, 100));
        assert_eq!(p.in_use, 2);
    }

    /// Review fix: a small arrival must not jump a queued larger prompt
    /// between ticks — token-gated admission stays FIFO even when the
    /// remaining budget would cover the newcomer.
    #[test]
    fn token_gate_admission_is_fifo_between_ticks() {
        let mut p = batch_pool(40, None);
        let cancelled = vec![false; 3];
        let tokens = vec![10, 35, 5];
        assert!(p.acquire(0, 10)); // 30 budget left
        assert!(!p.acquire(1, 35), "35 > 30: queues");
        // 5 ≤ 30 would fit, but request 1 is ahead: FIFO queues it.
        assert!(!p.acquire(2, 5), "must not jump the queue");
        assert_eq!(p.live_queued(), 2);
        p.tick();
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(1), "FIFO head first");
        assert_eq!(p.try_admit(&cancelled, &tokens), Some(2));
        assert_eq!(p.in_use, 3);
    }

    #[test]
    fn token_gate_max_batch_caps_concurrency() {
        let mut p = batch_pool(1000, Some(2));
        let cancelled = vec![false; 4];
        let tokens = vec![10; 4];
        assert!(p.acquire(0, 10));
        assert!(p.acquire(1, 10));
        assert!(!p.acquire(2, 10), "max_batch reached");
        p.tick();
        assert_eq!(
            p.try_admit(&cancelled, &tokens),
            None,
            "budget alone cannot override max_batch"
        );
        // A departure frees batch headroom; the queue drains.
        assert_eq!(p.release(&cancelled, &tokens), Some(2));
        assert_eq!(p.in_use, 2);
        // Migrated-in joins bypass max_batch (handoff committed).
        assert!(!p.acquire_overflow(), "batch join, never a real slot");
        assert_eq!(p.in_use, 3);
        assert_eq!(p.release_overflow(&cancelled, &tokens), None);
        assert_eq!(p.in_use, 2);
    }

    /// With migration disabled, shard targeting is inert: the
    /// shard-targeted fleet is byte-identical to the legacy one under
    /// every balancer (no views are built, no RNG is drawn).
    #[test]
    fn shard_targeting_inert_without_migration() {
        let sc = scenario(38);
        let trace = trace_at_gap(150, 0.6, 21);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        for kind in BalancerKind::all() {
            let legacy = FleetConfig::sharded(3, 1, kind);
            let targeted = legacy
                .clone()
                .with_migration_targeting(MigrationTargeting::ShardTargeted);
            let a = run_fleet(&sc, &trace, &policy, &legacy);
            let b = run_fleet(&sc, &trace, &policy, &targeted);
            assert_eq!(a.records, b.records, "{kind}: targeting must be inert");
            assert_eq!(format!("{:?}", a.load), format!("{:?}", b.load));
            assert_eq!(b.load.migration_targeted, 0);
            assert_eq!(b.load.migration_fallbacks, 0);
        }
    }

    /// Shard-targeted migration routes re-prefills into concrete shards:
    /// the targeted count matches the per-shard `migrated_in` booking,
    /// every migration either targeted a shard or took the fallback, and
    /// the run is bit-reproducible.
    #[test]
    fn shard_targeted_migration_books_target_shards() {
        let sc = device_constrained_scenario(39);
        let trace = trace_at_gap(150, 1.0, 22);
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        let cfg = FleetConfig::sharded(4, 1, BalancerKind::LeastWork)
            .with_migration_targeting(MigrationTargeting::ShardTargeted);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        let migrated = out.records.iter().filter(|r| r.migrated).count();
        assert!(migrated > 0, "scenario must exercise migration");
        assert!(out.load.migration_targeted > 0, "targeting must fire");
        assert_eq!(
            out.load.migration_targeted + out.load.migration_fallbacks,
            migrated,
            "every server-bound migration is targeted or falls back"
        );
        let booked: usize = out.load.shards.iter().map(|s| s.migrated_in).sum();
        assert_eq!(booked, out.load.migration_targeted);
        // All shards warm throughout a static fleet: no fallbacks.
        assert_eq!(out.load.migration_fallbacks, 0);
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records, again.records);
        assert_eq!(format!("{:?}", out.load), format!("{:?}", again.load));
    }

    /// Per-shard fault injection degrades only the faulty shard: on a
    /// round-robin K=2 fleet with wide gaps (no queueing), requests
    /// landed on the healthy shard are byte-identical to the fault-free
    /// run, while the fleet's tail strictly worsens. The fault stream is
    /// separate, so a no-fault config is untouched.
    #[test]
    fn shard_fault_degrades_only_faulty_shard() {
        let sc = scenario(40);
        let trace = trace_at_gap(80, 30.0, 23);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let base_cfg = FleetConfig::sharded(2, 4, BalancerKind::RoundRobin);
        let fault_cfg = base_cfg.clone().with_shard_fault(
            1,
            ShardFault {
                spike_prob: 1.0,
                spike_scale: 10.0,
            },
        );
        let base = run_fleet(&sc, &trace, &policy, &base_cfg);
        let fault = run_fleet(&sc, &trace, &policy, &fault_cfg);
        // Round-robin deals arrivals 0,1,0,1,…: even indices land on the
        // healthy shard 0 and must be untouched.
        for (i, (b, f)) in base.records.iter().zip(&fault.records).enumerate() {
            if i % 2 == 0 {
                assert_eq!(b, f, "healthy-shard request {i} perturbed");
            }
        }
        let p99 = |o: &FleetOutcome| {
            Summary::of(&o.records.iter().map(|r| r.ttft).collect::<Vec<_>>()).p99
        };
        let mean = |o: &FleetOutcome| {
            Summary::of(&o.records.iter().map(|r| r.ttft).collect::<Vec<_>>()).mean
        };
        assert!(
            mean(&fault) > mean(&base),
            "degraded shard must worsen mean TTFT"
        );
        assert!(p99(&fault) > p99(&base), "degraded shard must worsen p99");
    }

    /// A mid-run outage forces the shard into Draining exactly once:
    /// queued streams re-route to the survivors, the victim finishes its
    /// in-flight work, retires a single time, and stops accruing
    /// shard-seconds (no leak: the total equals the per-shard lifetimes).
    #[test]
    fn outage_requeues_and_retires_exactly_once() {
        let sc = device_constrained_scenario(41);
        let trace = trace_at_gap(100, 0.2, 24);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        for targeting in [
            MigrationTargeting::BaseEndpoint,
            MigrationTargeting::ShardTargeted,
        ] {
            let cfg = FleetConfig::sharded(3, 1, BalancerKind::RoundRobin)
                .with_migration_targeting(targeting)
                .with_outage(10.0, 1);
            let out = run_fleet(&sc, &trace, &policy, &cfg);
            assert_eq!(out.records.len(), trace.len(), "{targeting}: liveness");
            assert_eq!(out.load.outage_count(), 1, "{targeting}");
            assert!(
                out.load.outage_requeues > 0,
                "{targeting}: an overloaded shard must have had a queue to re-route"
            );
            assert_eq!(out.load.retire_count(1), 1, "{targeting}: exactly one retire");
            let lifetimes: f64 = out.load.shards.iter().map(|s| s.lifetime_seconds).sum();
            assert!(
                (out.load.shard_seconds - lifetimes).abs() < 1e-9,
                "{targeting}: shard-seconds must decompose per shard"
            );
            assert!(
                out.load.shards[1].lifetime_seconds < out.load.horizon,
                "{targeting}: the dead shard must stop billing before the end"
            );
        }
    }

    /// A second outage on the same (already draining) shard is a no-op:
    /// one Outage event, at most one Retire, no double-billing.
    #[test]
    fn double_outage_is_idempotent() {
        let sc = scenario(42);
        let trace = trace_at_gap(80, 0.3, 25);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::sharded(2, 1, BalancerKind::JoinShortestQueue)
            .with_outage(5.0, 1)
            .with_outage(6.0, 1);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        assert_eq!(out.load.outage_count(), 1, "second outage must be a no-op");
        assert!(out.load.retire_count(1) <= 1);
        let lifetimes: f64 = out.load.shards.iter().map(|s| s.lifetime_seconds).sum();
        assert!((out.load.shard_seconds - lifetimes).abs() < 1e-9);
    }

    /// Killing the only shard of a K=1 fleet degrades to drain-and-serve
    /// (there is nowhere to re-route): the run still terminates with
    /// every request resolved.
    #[test]
    fn outage_on_single_shard_fleet_still_terminates() {
        let sc = scenario(43);
        let trace = trace_at_gap(40, 0.3, 26);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::bounded(1).with_outage(2.0, 0);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        assert_eq!(out.load.outage_count(), 1);
        assert_eq!(
            out.load.outage_requeues, 0,
            "staying on the draining shard is not a re-route"
        );
    }

    /// An outage scheduled onto a shard index that never exists is a
    /// clean no-op, and outage events are recorded in the scale-event
    /// stream with the `Outage` kind (not conflated with scale-in).
    #[test]
    fn outage_event_bookkeeping() {
        let sc = scenario(44);
        let trace = trace_at_gap(60, 0.5, 27);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::sharded(2, 1, BalancerKind::RoundRobin)
            .with_outage(3.0, 7) // never provisioned: no-op
            .with_outage(4.0, 0);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        assert_eq!(out.load.outage_count(), 1);
        let kinds: Vec<Sek> = out.load.scale_events.iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&Sek::Outage));
        assert!(!kinds.contains(&Sek::DrainStart), "outage is not a scale-in");
    }

    // -----------------------------------------------------------------
    // Continuous batching: fleet-level behavior
    // -----------------------------------------------------------------

    use crate::sim::batching::BatchLatencyCurve;

    fn continuous_cfg(budget: u32, tick: f64, curve: BatchLatencyCurve) -> ContinuousBatchConfig {
        ContinuousBatchConfig {
            prefill_tokens_per_tick: budget,
            tick_interval: tick,
            max_batch: None,
            curve,
        }
    }

    /// With an effectively unlimited token budget and a flat latency
    /// curve, continuous batching degenerates to the unlimited-pool
    /// replay: admission is immediate and decode gaps are unscaled, so
    /// the records are byte-identical (tick events change only the
    /// event count, never a draw or a grant time).
    #[test]
    fn continuous_infinite_budget_flat_curve_matches_unlimited_replay() {
        let sc = scenario(45);
        let trace = WorkloadSpec::alpaca(200).at_rate(2.0).generate(28);
        let policy = Policy::simple(PolicyKind::StochS, 0.7, false);
        let legacy = run_fleet(&sc, &trace, &policy, &FleetConfig::replay(false));
        let cont = FleetConfig {
            batching: BatchingMode::Continuous(continuous_cfg(
                u32::MAX,
                0.5,
                BatchLatencyCurve::Flat,
            )),
            ..FleetConfig::replay(false)
        };
        let out = run_fleet(&sc, &trace, &policy, &cont);
        assert_eq!(legacy.records, out.records);
        assert_eq!(out.load.server_slots, None);
        assert!(out.load.events_processed > legacy.load.events_processed, "ticks fired");
        assert!(out.load.token_budget_utilization().is_some());
    }

    /// The batch latency curve reaches the perceived stream: with
    /// concurrent streams in the batch, a steep curve stretches decode
    /// past the consumption rate — identical TTFTs (prefill and
    /// admission are curve-independent), strictly longer delivered
    /// streams.
    #[test]
    fn batch_curve_slows_decode_but_not_ttft() {
        // DeepSeek decode (~30 tok/s) so a realistic slowdown crosses
        // the r_c = 5 tok/s pacing floor and becomes visible post-
        // smoothing.
        let sc = Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed: 46,
                ..Default::default()
            },
        );
        let trace = trace_at_gap(24, 0.25, 29);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let run_curve = |curve: BatchLatencyCurve| {
            let cfg = FleetConfig {
                batching: BatchingMode::Continuous(continuous_cfg(u32::MAX, 0.25, curve)),
                ..FleetConfig::replay(false)
            };
            run_fleet(&sc, &trace, &policy, &cfg)
        };
        let flat = run_curve(BatchLatencyCurve::Flat);
        let steep = run_curve(BatchLatencyCurve::Linear { alpha: 3.0 });
        let dur = |o: &FleetOutcome| -> f64 {
            o.records
                .iter()
                .map(|r| r.ttft + r.tbts.iter().sum::<f64>())
                .sum::<f64>()
        };
        for (f, s) in flat.records.iter().zip(&steep.records) {
            assert_eq!(
                f.ttft.to_bits(),
                s.ttft.to_bits(),
                "prefill/admission must be curve-independent"
            );
        }
        assert!(
            dur(&steep) > dur(&flat) * 1.2,
            "a steep batch curve must stretch delivered streams: {:.1}s vs {:.1}s",
            dur(&steep),
            dur(&flat)
        );
        // Batch-size telemetry recorded the crowding.
        let peak = steep.load.peak_batch();
        assert!(peak > 1, "concurrent arrivals must share the batch, peak={peak}");
        assert!(!steep.load.batch_timeline.is_empty());
        let times: Vec<f64> = steep.load.batch_timeline.iter().map(|b| b.time).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "timeline in event order");
    }

    /// Token-gated admission under sustained overload: every request
    /// still resolves (ticks drain the queue FIFO), queue delays are
    /// real, and the token-budget utilization is a sane ratio.
    #[test]
    fn continuous_overload_queues_on_token_budget_and_stays_live() {
        let sc = scenario(47);
        // ~60 tokens/s offered prompts vs a 40 tokens/s budget.
        let trace = trace_at_gap(120, 0.5, 30);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig {
            batching: BatchingMode::Continuous(continuous_cfg(
                20,
                0.5,
                BatchLatencyCurve::Knee { knee: 8, alpha: 0.05 },
            )),
            ..FleetConfig::replay(false)
        };
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len(), "liveness under token overload");
        assert!(
            out.load.server_queue_delay.max > 0.0,
            "an overloaded token budget must queue admissions"
        );
        let util = out.load.token_budget_utilization().expect("continuous mode");
        assert!(util > 0.0 && util.is_finite(), "token utilization {util}");
        assert_eq!(out.load.release_underflows, 0);
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records, again.records, "continuous runs are deterministic");
        assert_eq!(format!("{:?}", out.load), format!("{:?}", again.load));
    }

    /// Continuous batching composes with the autoscaler: the
    /// token-backlog/batch-depth signal scales the fleet out under a
    /// burst, cold shards are provisioned frozen (and accrue no token
    /// capacity until they warm — the review fix), queued prefills
    /// drain on warm-up, and the run stays live and bit-reproducible.
    #[test]
    fn continuous_batching_with_autoscaler_stays_live() {
        let sc = scenario(50);
        let trace = burst_then_calm(100, 20, 33);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::sharded(1, 1, BalancerKind::JoinShortestQueue)
            .with_batching(BatchingMode::Continuous(continuous_cfg(
                32,
                0.25,
                BatchLatencyCurve::Knee { knee: 8, alpha: 0.05 },
            )))
            .with_autoscale(eager_reactive(1, 3, 1.0));
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len(), "liveness under burst + scaling");
        assert!(
            out.load.scale_out_count() >= 1,
            "the batch-depth signal must trigger scale-out"
        );
        let util = out.load.token_budget_utilization().expect("continuous mode");
        assert!(util > 0.0 && util.is_finite());
        assert_eq!(out.load.release_underflows, 0);
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records, again.records);
        assert_eq!(format!("{:?}", out.load), format!("{:?}", again.load));
    }

    // -----------------------------------------------------------------
    // Migration queue-delay estimate audit (this PR's bugfix sweep)
    // -----------------------------------------------------------------

    /// Empty-queue consistency: on an idle fleet a migrating stream
    /// admits instantly, so the predicted admission delay must be
    /// exactly 0 — making shard-targeted migration byte-identical to
    /// the base-endpoint fallback when shard RTTs are zero. The old
    /// work-over-capacity estimate charged phantom delay for the
    /// migrating stream's *own* slot booking (the queued-ahead
    /// off-by-one): at K=1 × 1 slot the only candidate shard is the
    /// stream's own, whose outstanding work is exactly the stream
    /// itself, and the old formula priced `own_sample / slots` seconds
    /// of nonexistent queueing into `t_m`. The K=2 × 4-slot variant
    /// pins the spare-real-slot rule on truly idle candidates.
    #[test]
    fn idle_fleet_shard_targeted_estimate_is_zero_and_matches_base_endpoint() {
        let sc = device_constrained_scenario(48);
        let trace = trace_at_gap(60, 40.0, 31);
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        for (k, slots) in [(1usize, 1usize), (2, 4)] {
            let base = run_fleet(
                &sc,
                &trace,
                &policy,
                &FleetConfig::sharded(k, slots, BalancerKind::RoundRobin),
            );
            let targeted = run_fleet(
                &sc,
                &trace,
                &policy,
                &FleetConfig::sharded(k, slots, BalancerKind::RoundRobin)
                    .with_migration_targeting(MigrationTargeting::ShardTargeted),
            );
            let migrated = base.records.iter().filter(|r| r.migrated).count();
            assert!(migrated > 0, "K={k}: scenario must exercise migration");
            assert!(targeted.load.migration_targeted > 0, "K={k}");
            assert_eq!(
                base.records, targeted.records,
                "K={k}×{slots}: idle-fleet targeting must price zero queue delay"
            );
        }
    }

    /// Draining-shard consistency: a draining shard is never a
    /// re-prefill target, so its (infinite, really) admission delay is
    /// never priced — the migration falls back to the base endpoint and
    /// is counted, instead of booking into a dying pool.
    #[test]
    fn draining_fleet_migrations_fall_back_not_priced() {
        let sc = device_constrained_scenario(49);
        let trace = trace_at_gap(50, 2.0, 32);
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        let cfg = FleetConfig::bounded(2)
            .with_migration_targeting(MigrationTargeting::ShardTargeted)
            .with_outage(0.0, 0);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        let migrated = out.records.iter().filter(|r| r.migrated).count();
        assert!(migrated > 0, "scenario must exercise migration");
        assert!(
            out.load.migration_fallbacks > 0,
            "migrations after the outage must fall back, not target the draining shard"
        );
        // Only resolutions racing the t=0 outage (the first arrival) can
        // have targeted a still-warm shard.
        assert!(
            out.load.migration_targeted <= 1,
            "draining shard must not be targeted: {} targeted",
            out.load.migration_targeted
        );
        let booked: usize = out.load.shards.iter().map(|s| s.migrated_in).sum();
        assert_eq!(booked, out.load.migration_targeted);
    }

    /// A zero-second cold start still goes through the cold → warm
    /// transition (same event order), just instantaneously.
    #[test]
    fn zero_delay_cold_start_is_live() {
        let sc = scenario(37);
        let trace = burst_then_calm(80, 10, 20);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::sharded(1, 1, BalancerKind::JoinShortestQueue)
            .with_autoscale(eager_reactive(1, 3, 0.0));
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        assert!(out.load.scale_out_count() >= 1);
        assert_eq!(out.load.cold_start_seconds, 0.0);
    }

    /// Regression pin for the hot-path allocation sweep: the migration
    /// path now *borrows* the target endpoint ([`MigrationServer`])
    /// instead of cloning a `ServerEndpoint` per resolved stream, and
    /// the per-request RNG resumes in place instead of being cloned out
    /// of the state table. Both rewrites must be byte-invisible: a
    /// migration-heavy run (shard-targeted re-prefills, heterogeneous
    /// RTTs so `extra_rtt + delay` exercises real float folds, a shard
    /// fault, and a mid-run outage forcing base-endpoint fallbacks) is
    /// bit-reproducible and byte-identical across both event-queue
    /// backends.
    #[test]
    fn migration_heavy_run_byte_stable_across_backends() {
        let sc = device_constrained_scenario(53);
        let trace = trace_at_gap(150, 1.0, 41);
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        let cfg = FleetConfig::sharded(3, 2, BalancerKind::LeastWork)
            .with_shard_rtts(vec![0.0, 0.05, 0.12])
            .with_migration_targeting(MigrationTargeting::ShardTargeted)
            .with_shard_fault(
                1,
                ShardFault {
                    spike_prob: 0.3,
                    spike_scale: 4.0,
                },
            )
            .with_outage(60.0, 2);
        let wheel = run_fleet(&sc, &trace, &policy, &cfg);
        // The scenario actually exercises the rewritten paths.
        assert!(
            wheel.records.iter().filter(|r| r.migrated).count() > 0,
            "scenario must exercise migration"
        );
        assert!(
            wheel.load.migration_targeted > 0,
            "scenario must book shard-targeted re-prefills"
        );
        // Bit-reproducible (the RNG resumes exactly where the old clone
        // did), and byte-identical on the heap reference backend.
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(wheel.records, again.records, "not reproducible");
        let heap = run_fleet(
            &sc,
            &trace,
            &policy,
            &cfg.clone().with_event_queue(EventQueueKind::Heap),
        );
        assert_eq!(wheel.records, heap.records, "wheel/heap records diverged");
        assert_eq!(
            format!("{:?}", wheel.load),
            format!("{:?}", heap.load),
            "wheel/heap load reports diverged"
        );
    }

    /// The JSQ/least-work incremental index is a pure optimization: a
    /// churny autoscaled run (scale-out rebuilds, drains, retirements)
    /// under each indexed balancer is byte-identical across backends and
    /// reproducible — and the debug-build parity assert inside
    /// `pick_indexed` re-derives every pick from a full linear scan.
    #[test]
    fn indexed_balancers_byte_stable_under_autoscaling_churn() {
        let sc = scenario(59);
        let trace = burst_then_calm(120, 40, 43);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        for balancer in [BalancerKind::JoinShortestQueue, BalancerKind::LeastWork] {
            let cfg = FleetConfig::sharded(2, 1, balancer)
                .with_autoscale(eager_reactive(1, 5, 0.5))
                .with_outage(25.0, 0);
            let wheel = run_fleet(&sc, &trace, &policy, &cfg);
            assert_eq!(wheel.records.len(), trace.len());
            let heap = run_fleet(
                &sc,
                &trace,
                &policy,
                &cfg.clone().with_event_queue(EventQueueKind::Heap),
            );
            assert_eq!(
                wheel.records, heap.records,
                "{balancer}: wheel/heap records diverged under churn"
            );
            assert_eq!(
                format!("{:?}", wheel.load),
                format!("{:?}", heap.load),
                "{balancer}: wheel/heap load reports diverged under churn"
            );
        }
    }

    // -----------------------------------------------------------------
    // Paged KV: memory pressure, prefix caching, KV-aware failover,
    // and the grouped config surface
    // -----------------------------------------------------------------

    use crate::trace::generator::{LengthModel, SessionSpec};

    fn kv_cfg(pages: usize, chunk: u32, cache: bool) -> KvConfig {
        KvConfig {
            pages,
            block_tokens: 16,
            chunk_tokens: chunk,
            tick_interval: 0.25,
            prefix_caching: cache,
            curve: BatchLatencyCurve::Flat,
            ..KvConfig::default()
        }
    }

    /// Satellite pin: the grouped sub-config surface (`with_server` /
    /// `with_control` / `with_faults`) and the historical flat builder
    /// chain describe the same fleet — the grouped accessors round-trip
    /// the flat chain, and a migration-heavy paged-KV run (heterogeneous
    /// RTTs, a shard fault, a mid-run outage, the heap backend) is
    /// byte-identical either way.
    #[test]
    fn grouped_config_surface_matches_flat_builder_shims() {
        let sc = device_constrained_scenario(61);
        let trace = trace_at_gap(80, 1.0, 44);
        let policy = Policy::simple(PolicyKind::StochD, 1.0, true);
        let kv = kv_cfg(256, 4096, true);
        let fault = ShardFault {
            spike_prob: 0.3,
            spike_scale: 4.0,
        };
        let flat = FleetConfig::sharded(3, 2, BalancerKind::LeastWork)
            .with_shard_rtts(vec![0.0, 0.05, 0.12])
            .with_migration_targeting(MigrationTargeting::ShardTargeted)
            .with_shard_fault(1, fault)
            .with_outage(30.0, 2)
            .with_event_queue(EventQueueKind::Heap)
            .with_kv(kv);
        let grouped = FleetConfig::sharded(1, 1, BalancerKind::RoundRobin)
            .with_server(ServerSpec {
                shards: 3,
                server_slots: Some(2),
                shard_rtts: vec![0.0, 0.05, 0.12],
                batching: BatchingMode::PagedKv(kv),
                pricing: PricingMode::JoinTime,
            })
            .with_control(ControlSpec {
                balancer: BalancerKind::LeastWork,
                autoscale: None,
                migration_targeting: MigrationTargeting::ShardTargeted,
                event_queue: EventQueueKind::Heap,
                price_base_tails: true,
            })
            .with_faults(FaultPlan::default().fault(1, fault).outage(30.0, 2));
        assert_eq!(
            format!("{:?}", flat.server_spec()),
            format!("{:?}", grouped.server_spec())
        );
        assert_eq!(
            format!("{:?}", flat.control_spec()),
            format!("{:?}", grouped.control_spec())
        );
        assert_eq!(
            format!("{:?}", flat.fault_plan()),
            format!("{:?}", grouped.fault_plan())
        );
        let fa = run_fleet(&sc, &trace, &policy, &flat);
        let fb = run_fleet(&sc, &trace, &policy, &grouped);
        assert_eq!(fa.records, fb.records, "grouped and flat configs diverged");
        assert_eq!(format!("{:?}", fa.load), format!("{:?}", fb.load));
    }

    /// Tentpole: a page pool sized below the working set preempts the
    /// lowest-priority stream under decode growth — the run stays live,
    /// every stream keeps its token accounting (the §4.3 no-gaps /
    /// no-dups invariant — one inter-token gap stretches, counts never
    /// change), and the run is bit-stable across event-queue backends.
    #[test]
    fn paged_kv_memory_pressure_preempts_and_conserves_streams() {
        let sc = scenario(62);
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed { gap: 0.2 },
            prompt: LengthModel::new(120.0, 0.3, 64, 200),
            output: LengthModel::new(220.0, 0.3, 120, 320),
            ..WorkloadSpec::alpaca(40)
        };
        let trace = spec.generate(45);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::replay(false).with_kv(kv_cfg(20, 4096, false));
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len(), "liveness under memory pressure");
        assert!(
            out.load.kv_preemptions > 0,
            "a 20-page pool under decode growth must preempt"
        );
        assert_eq!(out.load.prefix_hit_rate(), None, "caching off counts no lookups");
        assert!(out.load.shards[0].kv_pages_peak > 0);
        assert_eq!(out.load.shards[0].kv_pages_total, 20);
        for rec in &out.records {
            assert_eq!(rec.tbts.len() as u32 + 1, rec.output_len, "req {}", rec.id);
            assert!(rec.tbts.iter().all(|&t| t > 0.0), "req {}", rec.id);
        }
        assert_eq!(out.load.release_underflows, 0);
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records, again.records, "preemption must be deterministic");
        let heap = run_fleet(
            &sc,
            &trace,
            &policy,
            &cfg.clone().with_event_queue(EventQueueKind::Heap),
        );
        assert_eq!(out.records, heap.records, "wheel/heap diverged under preemption");
        assert_eq!(format!("{:?}", out.load), format!("{:?}", heap.load));
    }

    /// Tentpole: a hard outage in paged mode loses in-flight KV — every
    /// mid-decode stream on the dead shard is forced to re-prefill its
    /// full context, booked onto the migration target through the §4.3
    /// over-commit machinery, and token conservation still holds.
    #[test]
    fn paged_outage_forces_mid_decode_reprefill() {
        let sc = Scenario::new(
            ServerProfile::deepseek_v25(),
            DeviceProfile::xiaomi14_qwen0b5(),
            Constraint::Server,
            SimConfig {
                seed: 63,
                ..Default::default()
            },
        );
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed { gap: 0.5 },
            output: LengthModel::new(250.0, 0.3, 150, 400),
            ..WorkloadSpec::alpaca(40)
        };
        let trace = spec.generate(46);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let base = FleetConfig::sharded(2, 2, BalancerKind::RoundRobin)
            .with_kv(kv_cfg(4096, 1024, false));
        let cfg = base.clone().with_outage(8.0, 0);
        let calm = run_fleet(&sc, &trace, &policy, &base);
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len());
        assert!(
            out.load.kv_forced_reprefills > 0,
            "mid-decode streams on the dead shard must re-prefill"
        );
        assert_eq!(calm.load.kv_forced_reprefills, 0, "no outage, no KV loss");
        // Forced migrations book their targets through the §4.3
        // machinery, so the booking ledger still balances.
        let booked: usize = out.load.shards.iter().map(|s| s.migrated_in).sum();
        assert_eq!(booked, out.load.migration_targeted);
        for rec in &out.records {
            assert_eq!(rec.tbts.len() as u32 + 1, rec.output_len, "req {}", rec.id);
            assert!(rec.tbts.iter().all(|&t| t > 0.0), "req {}", rec.id);
        }
        // The forced re-prefill is visible end-to-end: total delivered
        // stream time strictly exceeds the outage-free run's.
        let dur = |o: &FleetOutcome| -> f64 {
            o.records
                .iter()
                .map(|r| r.ttft + r.tbts.iter().sum::<f64>())
                .sum()
        };
        assert!(
            dur(&out) > dur(&calm),
            "KV loss must stretch delivered streams: {:.3}s vs {:.3}s",
            dur(&out),
            dur(&calm)
        );
        assert_eq!(out.load.release_underflows, 0);
        let again = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records, again.records);
        assert_eq!(format!("{:?}", out.load), format!("{:?}", again.load));
    }

    /// Acceptance: prefix caching on a session-heavy trace hits (>0
    /// hit-rate) and strictly lowers mean TTFT vs the same `KvConfig`
    /// with caching off. The cache draws no randomness, so the two runs
    /// share every draw — hits can only shrink prefill samples and
    /// admission charges, never grow them.
    #[test]
    fn prefix_caching_hits_and_strictly_lowers_mean_ttft() {
        let sc = scenario(64);
        let trace = SessionSpec::chat(8, 5, 2.0).generate(47);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let on = run_fleet(
            &sc,
            &trace,
            &policy,
            &FleetConfig::replay(false).with_kv(kv_cfg(4096, 4096, true)),
        );
        let off = run_fleet(
            &sc,
            &trace,
            &policy,
            &FleetConfig::replay(false).with_kv(kv_cfg(4096, 4096, false)),
        );
        assert_eq!(on.records.len(), trace.len());
        let rate = on.load.prefix_hit_rate().expect("caching on performs lookups");
        assert!(rate > 0.0, "session prompts must hit the prefix index");
        assert!(on.load.prefix_hits > 0 && on.load.prefix_lookups >= on.load.prefix_hits);
        assert_eq!(off.load.prefix_hit_rate(), None, "caching off counts no lookups");
        let mean = |o: &FleetOutcome| -> f64 {
            o.records.iter().map(|r| r.ttft).sum::<f64>() / o.records.len() as f64
        };
        assert!(
            mean(&on) < mean(&off),
            "prefix hits must strictly lower mean TTFT: {:.4} vs {:.4}",
            mean(&on),
            mean(&off)
        );
        // Per-request: caching never makes any TTFT worse.
        for (a, b) in on.records.iter().zip(&off.records) {
            assert!(a.ttft <= b.ttft + 1e-12, "req {} regressed under caching", a.id);
        }
    }

    /// Sarathi chunking: prompts larger than one chunk accrue budget
    /// across ticks instead of jumping the gate — admission queues form
    /// (real queue delay), yet every oversized prompt eventually admits
    /// and the token telemetry stays defined.
    #[test]
    fn oversized_prompts_chunk_across_ticks_and_stay_live() {
        let sc = scenario(65);
        let spec = WorkloadSpec {
            arrival: Arrival::Fixed { gap: 1.0 },
            prompt: LengthModel::new(200.0, 0.2, 100, 400),
            ..WorkloadSpec::alpaca(30)
        };
        let trace = spec.generate(48);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let cfg = FleetConfig::replay(false).with_kv(kv_cfg(4096, 32, false));
        let out = run_fleet(&sc, &trace, &policy, &cfg);
        assert_eq!(out.records.len(), trace.len(), "oversized prompts must still admit");
        assert!(
            out.load.server_queue_delay.max > 0.0,
            "chunked prefill must queue admissions across ticks"
        );
        let util = out
            .load
            .token_budget_utilization()
            .expect("paged mode has a token gate");
        assert!(util > 0.0 && util.is_finite());
        assert_eq!(out.load.kv_preemptions, 0, "no memory pressure in a 4096-page pool");
    }
}
