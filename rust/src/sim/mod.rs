//! Deterministic workload simulation.
//!
//! [`delivery`] models the user-side token consumption schedule (§4.3);
//! [`engine`] replays a trace against simulated endpoints under a policy,
//! producing per-request [`crate::metrics::RequestRecord`]s. Every run is
//! reproducible from its seed; the paper's "mean over 10 runs" becomes a
//! seed sweep.

pub mod delivery;
pub mod engine;

pub use engine::{Scenario, SimConfig};
