//! Deterministic workload simulation.
//!
//! Three layers, one request code path:
//!
//! * [`delivery`] models the user-side token consumption schedule (§4.3):
//!   tokens are paced at the consumption rate `r_c`, a buffer absorbs
//!   generation jitter, and tokens that miss the schedule count toward
//!   `delay_num`.
//! * [`engine`] holds the per-request trajectory — the prefill race,
//!   loser cancellation, token-level migration with buffered handoff,
//!   and unified cost metering — parameterized by the absolute times the
//!   contended resources were granted, plus the [`engine::Scenario`]
//!   front door.
//! * [`fleet`] is the discrete-event loop that produces those grant
//!   times: a binary-heap event queue in which N concurrent requests
//!   contend for a server with a configurable concurrency limit
//!   (`FleetConfig::server_slots`) plus FIFO admission queue, and for
//!   the single-flight device. Dispatch and migration decisions flow
//!   through `coordinator::policy` / `coordinator::migration` unchanged.
//!
//! # Fleet model and knobs
//!
//! * `FleetConfig::replay(device_queueing)` — the degenerate
//!   configuration: unlimited server admission. This reproduces the
//!   paper's per-request replay methodology exactly (server TTFT
//!   distributions already fold the provider's own queueing in
//!   statistically); [`engine::Scenario::run`] is this configuration.
//! * `FleetConfig { server_slots: Some(c), .. }` — a bounded admission
//!   pool: requests beyond `c` concurrent admissions wait in FIFO order,
//!   and their perceived TTFT includes the queue delay. Load-dependent
//!   metrics (queue delay, busy seconds, utilization, horizon) surface
//!   in [`crate::metrics::LoadReport`].
//! * Arrival processes live in `trace::generator`: Poisson and Gamma
//!   inter-arrivals (`Arrival::Poisson` / `Arrival::Gamma` — CV above or
//!   below 1 for burstier or smoother-than-Poisson traffic), fixed gaps,
//!   and per-user session workloads (`SessionSpec`) that overlay many
//!   users' request streams into one fleet trace.
//!
//! Every run is reproducible bit-for-bit from `SimConfig.seed`: the event
//! heap breaks time ties deterministically and per-request RNG streams
//! are forked in trace order, independent of event interleaving. The
//! paper's "mean over 10 runs" becomes a seed sweep.

pub mod delivery;
pub mod engine;
pub mod fleet;

pub use engine::{Scenario, SimConfig};
pub use fleet::{FleetConfig, FleetOutcome};
