//! Deterministic workload simulation.
//!
//! Five layers, one request code path:
//!
//! * [`delivery`] models the user-side token consumption schedule (§4.3):
//!   tokens are paced at the consumption rate `r_c`, a buffer absorbs
//!   generation jitter, and tokens that miss the schedule count toward
//!   `delay_num`.
//! * [`engine`] holds the per-request trajectory — the prefill race,
//!   loser cancellation, token-level migration with buffered handoff,
//!   and unified cost metering — parameterized by the absolute times the
//!   contended resources were granted, plus the [`engine::Scenario`]
//!   front door.
//! * [`balancer`] is the shard-selection layer: a [`balancer::Balancer`]
//!   trait with round-robin, join-shortest-queue, power-of-two-choices,
//!   and least-work implementations, selected by
//!   [`balancer::BalancerKind`]. Balancers skip non-admitting (cold or
//!   draining) shards.
//! * [`autoscaler`] is the capacity-policy layer: an
//!   [`autoscaler::Autoscaler`] trait (none / reactive queue-depth /
//!   TTFT-target) that lets the shard count react to load mid-run, with
//!   cold-start penalties from [`autoscaler::ColdStartSpec`] (Appendix
//!   B's load-time model) on scale-out and drain-then-retire semantics
//!   on scale-in.
//! * [`fleet`] is the discrete-event loop that produces the resource
//!   grant times: a pluggable [`event_queue::EventQueue`] (timing wheel
//!   by default, binary heap as the byte-parity reference) in which N
//!   concurrent requests contend for a *sharded* server fleet
//!   (`FleetConfig::shards` replicas, each with
//!   `FleetConfig::server_slots` admission slots, its own FIFO queue,
//!   and an optional per-shard RTT offset) and for the single-flight
//!   device. Dispatch and migration decisions flow through
//!   `coordinator::policy` / `coordinator::migration` unchanged.
//! * [`zones`] scales one cell across cores: [`zones::ZonedFleetConfig`]
//!   splits the trace round-robin into Z independent zones (each a full
//!   fleet with its own shards/balancer/autoscaler/batching and an
//!   optional zone-wide RTT offset), runs them on scoped worker threads
//!   (`DISCO_THREADS`-bounded), and merges records and load reports
//!   bit-reproducibly — per-zone RNG streams derive from the zone id,
//!   never thread identity, so output is byte-identical for any worker
//!   count and Z=1 is byte-identical to [`fleet::run_fleet`].
//!
//! # Fleet model and knobs
//!
//! * `FleetConfig::replay(device_queueing)` — the degenerate
//!   configuration: one shard, unlimited admission. This reproduces the
//!   paper's per-request replay methodology exactly (server TTFT
//!   distributions already fold the provider's own queueing in
//!   statistically); [`engine::Scenario::run`] is this configuration.
//! * `FleetConfig::bounded(c)` — one shard with `c` admission slots:
//!   requests beyond `c` concurrent admissions wait in FIFO order, and
//!   their perceived TTFT includes the queue delay.
//! * `FleetConfig::sharded(k, c, balancer)` — K replicas with `c` slots
//!   each, fronted by the chosen balancer; heterogeneous placement via
//!   `with_shard_rtts`. Load-dependent metrics (queue delay, busy
//!   seconds, utilization, per-shard breakdown, imbalance) surface in
//!   [`crate::metrics::LoadReport`].
//! * `FleetConfig::with_autoscale(cfg)` — attach an
//!   [`autoscaler::AutoscaleConfig`]: K becomes dynamic (scale-out pays
//!   a cold-start load delay, scale-in drains before retiring), and the
//!   shard-count timeline, scale events, cold-start seconds, and
//!   provisioned shard-seconds land in the load report.
//! * `FleetConfig::with_batching(BatchingMode::Continuous(..))` — swap
//!   the per-shard slot pool for continuous batching ([`batching`]):
//!   prefill admission gated by a prompt-token budget per scheduling
//!   tick, decode streams sharing the shard's batch with per-token
//!   latency scaled by a pluggable [`batching::BatchLatencyCurve`]. The
//!   default [`batching::BatchingMode::SlotLegacy`] is byte-identical
//!   to the historical slot fleet.
//! * `FleetConfig::with_kv(KvConfig)` — paged KV admission ([`kv`]):
//!   each shard owns a fixed pool of KV blocks; prefills allocate
//!   pages, decode grows usage, memory pressure preempts the
//!   lowest-priority stream (evict-and-re-prefill), prefix-cache hits
//!   skip the cached fraction of prefill, and a hard outage loses
//!   in-flight KV, forcing mid-decode re-prefill at the migration
//!   target.
//! * Grouped config surface: the flat builder chain is organized into
//!   [`fleet::ServerSpec`] (shards, rtts, slots, batching/kv),
//!   [`fleet::ControlSpec`] (balancer, autoscaler, migration targeting,
//!   event queue), and [`fleet::FaultPlan`] (faults + outages) —
//!   `with_server` / `with_control` / `with_faults` — with the old
//!   per-field builders kept as thin delegating shims.
//! * `FleetConfig::with_migration_targeting(MigrationTargeting::ShardTargeted)`
//!   — §4.3 server-bound re-prefills pick a least-work admitting shard
//!   ([`balancer::pick_reprefill_target`]) and occupy its slot pool for
//!   the migrated stream's lifetime; `with_shard_fault` / `with_outage`
//!   inject per-shard TTFT degradation and scheduled mid-run shard
//!   failures (queued streams re-route to survivors, in-flight streams
//!   finish under connection draining).
//! * Arrival processes live in `trace::generator`: Poisson and Gamma
//!   inter-arrivals (`Arrival::Poisson` / `Arrival::Gamma` — CV above or
//!   below 1 for burstier or smoother-than-Poisson traffic), fixed gaps,
//!   per-user session workloads (`SessionSpec`) that overlay many users'
//!   request streams into one fleet trace, and the order-preserving
//!   `shuffle_payloads` / `interleave` helpers for randomized replays.
//!
//! Every run is reproducible bit-for-bit from `SimConfig.seed`: the event
//! queue breaks time ties deterministically under every backend
//! ([`event_queue::EventQueueKind`]), per-request RNG streams are forked
//! in trace order independent of event interleaving, and randomized
//! balancers draw from their own fleet-level stream. The paper's "mean
//! over 10 runs" becomes a seed sweep.

pub mod autoscaler;
pub mod balancer;
pub mod batching;
pub mod delivery;
pub mod engine;
pub mod event_queue;
pub mod fleet;
pub mod kv;
pub mod zones;

pub use autoscaler::{AutoscaleConfig, Autoscaler, AutoscalerKind, ColdStartSpec};
pub use balancer::{Balancer, BalancerKind, ShardView};
pub use batching::{BatchLatencyCurve, BatchingMode, ContinuousBatchConfig};
pub use engine::{Scenario, SimConfig};
pub use event_queue::{EventQueue, EventQueueKind};
pub use fleet::{
    ControlSpec, FaultPlan, FleetConfig, FleetOutcome, MigrationTargeting, ServerSpec,
    ShardFault, ShardOutage,
};
pub use kv::{KvConfig, KvGate};
pub use zones::{ZoneConfig, ZonedFleetConfig, ZonedOutcome};
