//! Pluggable discrete-event priority queue: a binary-heap reference
//! backend and a self-tuning calendar-queue / timing-wheel backend that
//! pops the *byte-identical* `(time, seq)` sequence.
//!
//! # Ordering contract
//!
//! Events are totally ordered by `(time, seq)` with [`f64::total_cmp`]
//! on the time and push order (`seq`, assigned by [`EventQueue::push`])
//! breaking exact time ties. Every backend must pop this exact total
//! order — the fleet simulator's bit-reproducibility rests on it, and
//! the parity suite at the bottom of this file asserts it over
//! randomized storms (heavy ties, far-future spikes, interleaved
//! push/pop, and non-finite times included).
//!
//! # Why the wheel preserves the order exactly
//!
//! The wheel never buckets by *real time intervals* — floating-point
//! interval arithmetic at bucket edges could misplace an event in
//! either direction. Bucket membership is defined purely by the
//! computed key
//!
//! ```text
//! key(t) = floor((t − origin) / width) as i64      (width > 0, finite)
//! ```
//!
//! which is a composition of monotone non-decreasing operations
//! (subtraction of a constant, division by a positive constant, floor,
//! saturating cast), so for finite times `a ≤ b ⇒ key(a) ≤ key(b)` —
//! equivalently `key(a) < key(b) ⇒ a < b`, and equal times always get
//! equal keys. Consequences the pop loop relies on:
//!
//! * draining buckets in ascending key order can never pop a later time
//!   before an earlier one, regardless of where `origin`/`width` landed;
//! * time ties always share a bucket, where a per-bucket binary heap
//!   breaks them by `seq` exactly like the reference backend.
//!
//! Non-finite times never enter the key function (`NaN as i64` is 0,
//! which would break monotonicity): per `total_cmp`, negative
//! non-finite times (−∞, negative NaN) sort before every finite time
//! and go straight to the current heap, and positive ones (+∞,
//! positive NaN) sort after everything finite and wait in a dedicated
//! far heap that only drains once all finite work is gone.

use std::collections::BinaryHeap;

/// Which event-queue backend a fleet run schedules on. Both backends
/// pop the identical `(time, seq)` total order (see the module docs),
/// so the choice affects throughput only — never results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EventQueueKind {
    /// Self-tuning calendar queue (timing wheel): O(1) amortized push
    /// and pop on the dense near-future event population a fleet run
    /// generates. The default.
    #[default]
    Wheel,
    /// Single global binary heap — the reference implementation the
    /// wheel is byte-parity-checked against (O(log n) per operation).
    Heap,
}

impl EventQueueKind {
    /// All backends, for parity matrices.
    pub fn all() -> [EventQueueKind; 2] {
        [EventQueueKind::Wheel, EventQueueKind::Heap]
    }

    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            EventQueueKind::Wheel => "wheel",
            EventQueueKind::Heap => "heap",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<EventQueueKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "wheel" | "calendar" | "timing-wheel" => EventQueueKind::Wheel,
            "heap" | "binary-heap" => EventQueueKind::Heap,
            _ => return None,
        })
    }
}

impl std::fmt::Display for EventQueueKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// One scheduled item: `(time, seq)` carries the total order, `item`
/// the payload. The `Ord` impl is *reversed* (earliest-first under a
/// max-heap), exactly like the fleet simulator's historical `Event`.
#[derive(Clone, Copy, Debug)]
struct Entry<T> {
    time: f64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == std::cmp::Ordering::Equal && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Number of ring buckets. One self-tuned window spans
/// `NUM_BUCKETS × width` seconds; with `width = span / NUM_BUCKETS` a
/// single window covers the whole pending population at reseed time,
/// and the per-bucket heap holds ~`len / NUM_BUCKETS` entries — the
/// comparison-count win over one global heap.
const NUM_BUCKETS: usize = 256;

/// The timing-wheel backend. Four regions, partitioned by key:
///
/// * `cur` — entries with `key < cur_key` (plus negative non-finite
///   times): a binary heap, the only pop source. Strictly earlier than
///   everything outside it (monotone keys), so popping it dry before
///   advancing is exact.
/// * `ring` — `NUM_BUCKETS` unsorted buckets covering keys
///   `[cur_key, cur_key + NUM_BUCKETS)`, one key per slot.
/// * `overflow` — finite times with `key ≥ cur_key + NUM_BUCKETS`
///   (or any finite time while unseeded); redistributed into the ring
///   as the window advances, and the reseed source when the ring runs
///   dry (that reseed is what makes the calendar self-tuning).
/// * `far` — positive non-finite times (+∞, positive NaN): after every
///   finite time per `total_cmp`, drained heap-ordered only when
///   nothing else remains.
#[derive(Debug)]
struct Wheel<T> {
    cur: BinaryHeap<Entry<T>>,
    ring: Vec<Vec<Entry<T>>>,
    ring_count: usize,
    overflow: Vec<Entry<T>>,
    far: BinaryHeap<Entry<T>>,
    origin: f64,
    width: f64,
    cur_key: i64,
    /// Until the first pop the wheel is unseeded: every finite push
    /// parks in `overflow`, and the first pop reseeds `origin`/`width`
    /// from the real span of the pending population.
    seeded: bool,
    len: usize,
}

impl<T> Wheel<T> {
    fn new() -> Wheel<T> {
        Wheel {
            cur: BinaryHeap::new(),
            ring: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            ring_count: 0,
            overflow: Vec::new(),
            far: BinaryHeap::new(),
            origin: 0.0,
            width: 1.0,
            cur_key: 0,
            seeded: false,
            len: 0,
        }
    }

    /// The monotone bucket key (callers guarantee `time` is finite).
    /// `as i64` saturates at the i64 range, which keeps monotonicity.
    fn key(&self, time: f64) -> i64 {
        ((time - self.origin) / self.width).floor() as i64
    }

    fn ring_slot(key: i64) -> usize {
        key.rem_euclid(NUM_BUCKETS as i64) as usize
    }

    /// Place one entry into the region its key selects. Only called
    /// while seeded (or during redistribution, which seeds first).
    fn place(&mut self, e: Entry<T>) {
        if !e.time.is_finite() {
            if e.time.is_sign_negative() {
                // −∞ / negative NaN: before every finite time.
                self.cur.push(e);
            } else {
                self.far.push(e);
            }
            return;
        }
        let k = self.key(e.time);
        if k < self.cur_key {
            self.cur.push(e);
        } else if k < self.cur_key + NUM_BUCKETS as i64 {
            self.ring[Self::ring_slot(k)].push(e);
            self.ring_count += 1;
        } else {
            self.overflow.push(e);
        }
    }

    fn push(&mut self, e: Entry<T>) {
        self.len += 1;
        if !self.seeded && e.time.is_finite() {
            self.overflow.push(e);
        } else {
            self.place(e);
        }
    }

    /// (Re)tune `origin`/`width` to the span of the finite overflow
    /// population and redistribute it. Called when `cur` and the ring
    /// are dry but overflow is not — the calendar-queue self-tuning
    /// step. Correctness does not depend on the tuning (membership is
    /// key-based), only throughput does.
    fn reseed(&mut self) {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for e in &self.overflow {
            if e.time < min {
                min = e.time;
            }
            if e.time > max {
                max = e.time;
            }
        }
        debug_assert!(min.is_finite(), "overflow holds finite times only");
        self.origin = min;
        let w = (max - min) / NUM_BUCKETS as f64;
        self.width = if w.is_finite() && w > 0.0 { w } else { 1.0 };
        self.cur_key = 0;
        self.seeded = true;
        for e in std::mem::take(&mut self.overflow) {
            self.place(e);
        }
    }

    /// Pull overflow entries that now fit the advanced window into the
    /// ring. Keeps the invariant that everything left in `overflow` has
    /// `key ≥ cur_key + NUM_BUCKETS` — without it, a later ring push
    /// with a smaller key than a parked overflow entry would pop first.
    fn redistribute_overflow(&mut self) {
        if self.overflow.is_empty() {
            return;
        }
        let horizon = self.cur_key + NUM_BUCKETS as i64;
        let mut i = 0;
        while i < self.overflow.len() {
            if self.key(self.overflow[i].time) < horizon {
                let e = self.overflow.swap_remove(i);
                self.place(e);
            } else {
                i += 1;
            }
        }
    }

    fn pop(&mut self) -> Option<Entry<T>> {
        loop {
            if let Some(e) = self.cur.pop() {
                self.len -= 1;
                return Some(e);
            }
            if self.len == 0 {
                return None;
            }
            if self.ring_count > 0 {
                // Advance to the next non-empty bucket. Each in-window
                // key owns exactly one slot, so scanning keys in
                // ascending order drains the ring in key order.
                for step in 0..NUM_BUCKETS as i64 {
                    let k = self.cur_key + step;
                    let slot = Self::ring_slot(k);
                    if self.ring[slot].is_empty() {
                        continue;
                    }
                    let bucket = std::mem::take(&mut self.ring[slot]);
                    self.ring_count -= bucket.len();
                    for e in bucket {
                        self.cur.push(e);
                    }
                    self.cur_key = k + 1;
                    self.redistribute_overflow();
                    break;
                }
            } else if !self.overflow.is_empty() {
                self.reseed();
            } else {
                // Only far (+∞ / positive-NaN) entries remain; drain
                // them heap-ordered.
                while let Some(e) = self.far.pop() {
                    self.cur.push(e);
                }
                debug_assert!(!self.cur.is_empty(), "len > 0 with every region empty");
            }
        }
    }
}

/// A discrete-event queue ordered by `(time, seq)`; `seq` is assigned
/// at push, so same-time events pop in push order under every backend.
#[derive(Debug)]
pub struct EventQueue<T> {
    backend: Backend<T>,
    /// Total pushes so far — also the next `seq`. Surfaces as
    /// `LoadReport::events_processed` (every pushed event is popped by
    /// a run that drains the queue).
    pushed: u64,
}

#[derive(Debug)]
enum Backend<T> {
    Heap(BinaryHeap<Entry<T>>),
    Wheel(Wheel<T>),
}

impl<T> EventQueue<T> {
    pub fn new(kind: EventQueueKind) -> EventQueue<T> {
        EventQueue {
            backend: match kind {
                EventQueueKind::Heap => Backend::Heap(BinaryHeap::new()),
                EventQueueKind::Wheel => Backend::Wheel(Wheel::new()),
            },
            pushed: 0,
        }
    }

    /// Schedule `item` at `time`; later pushes at the same time pop
    /// later (FIFO among ties).
    pub fn push(&mut self, time: f64, item: T) {
        let e = Entry {
            time,
            seq: self.pushed,
            item,
        };
        self.pushed += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(e),
            Backend::Wheel(w) => w.push(e),
        }
    }

    /// Pop the earliest `(time, seq)` entry.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop(),
            Backend::Wheel(w) => w.pop(),
        }?;
        Some((e.time, e.item))
    }

    /// Total events pushed over the queue's lifetime.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Wheel(w) => w.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Drive both backends through the identical push/pop schedule and
    /// assert the popped `(time-bits, payload)` sequences match bit for
    /// bit. `schedule` receives a callback per step: `Some(t)` pushes
    /// at `t`, `None` pops once.
    fn assert_parity(label: &str, schedule: impl Fn(&mut dyn FnMut(Option<f64>))) {
        let mut wheel: EventQueue<u64> = EventQueue::new(EventQueueKind::Wheel);
        let mut heap: EventQueue<u64> = EventQueue::new(EventQueueKind::Heap);
        let mut next_item = 0u64;
        let mut step = |op: Option<f64>| match op {
            Some(t) => {
                wheel.push(t, next_item);
                heap.push(t, next_item);
                next_item += 1;
            }
            None => {
                let w = wheel.pop().map(|(t, i)| (t.to_bits(), i));
                let h = heap.pop().map(|(t, i)| (t.to_bits(), i));
                assert_eq!(w, h, "{label}: pop mismatch");
            }
        };
        schedule(&mut step);
        assert_eq!(wheel.len(), heap.len(), "{label}: len mismatch");
        loop {
            let w = wheel.pop().map(|(t, i)| (t.to_bits(), i));
            let h = heap.pop().map(|(t, i)| (t.to_bits(), i));
            assert_eq!(w, h, "{label}: drain mismatch");
            if w.is_none() {
                break;
            }
        }
        assert_eq!(wheel.pushed(), heap.pushed());
    }

    #[test]
    fn storm_uniform_times() {
        let mut rng = Rng::new(0xE0E0);
        let times: Vec<f64> = (0..5000).map(|_| rng.f64() * 1000.0).collect();
        assert_parity("uniform", |step| {
            for &t in &times {
                step(Some(t));
            }
        });
    }

    #[test]
    fn storm_heavy_ties() {
        // Quantized times: many exact ties, which must pop in push
        // (seq) order.
        let mut rng = Rng::new(0x71E5);
        let times: Vec<f64> = (0..4000).map(|_| (rng.below(40) as f64) * 0.25).collect();
        assert_parity("ties", |step| {
            for &t in &times {
                step(Some(t));
            }
        });
    }

    #[test]
    fn storm_interleaved_push_pop() {
        // DES-style: pop advances a clock, pushes land at now + jitter
        // (with occasional exact-now ties and far-future spikes).
        let mut rng = Rng::new(0xD15C0);
        let mut ops: Vec<Option<f64>> = Vec::new();
        let mut now = 0.0f64;
        for _ in 0..200 {
            ops.push(Some(now + rng.f64()));
        }
        for _ in 0..6000 {
            if rng.chance(0.55) {
                ops.push(None);
                now += 0.01; // approximate clock advance for new pushes
            } else {
                let dt = if rng.chance(0.02) {
                    1.0e6 + rng.f64() // far-future spike
                } else if rng.chance(0.1) {
                    0.0 // exact tie with "now"
                } else {
                    rng.f64() * 2.0
                };
                ops.push(Some(now + dt));
            }
        }
        assert_parity("interleaved", |step| {
            for &op in &ops {
                step(op);
            }
        });
    }

    #[test]
    fn storm_all_same_time() {
        assert_parity("same-time", |step| {
            for _ in 0..1000 {
                step(Some(1.0));
            }
        });
    }

    #[test]
    fn storm_tiny_and_huge_spans() {
        // Denormal-scale spans and astronomically wide ones both key
        // monotonically (the cast saturates); order must survive.
        assert_parity("spans", |step| {
            for i in 0..100 {
                step(Some(1.0 + (i as f64) * f64::EPSILON));
            }
            for i in 0..100 {
                step(Some((i as f64) * 1.0e300));
            }
            step(Some(0.5));
            step(None);
            step(None);
        });
    }

    #[test]
    fn non_finite_times_follow_total_cmp_order() {
        // total_cmp: −NaN < −∞ < finite < +∞ < +NaN. The wheel must
        // agree with the heap on all of them.
        let nan = f64::NAN;
        let neg_nan = -f64::NAN;
        assert_parity("non-finite", |step| {
            for &t in &[3.0, f64::INFINITY, 1.0, neg_nan, nan, f64::NEG_INFINITY, 2.0] {
                step(Some(t));
            }
            step(None); // pops −NaN
            step(Some(0.25)); // push after partial drain
        });
    }

    #[test]
    fn push_behind_the_window_pops_first() {
        // An event scheduled before already-popped times (not produced
        // by the fleet loop, but the contract covers it): key < cur_key
        // routes to the current heap and pops next.
        assert_parity("behind-window", |step| {
            for i in 0..600 {
                step(Some(i as f64));
            }
            for _ in 0..300 {
                step(None);
            }
            step(Some(100.5)); // far behind the advanced window
            step(None);
        });
    }

    #[test]
    fn reseed_after_drain_handles_sparse_tail() {
        // Drain the first dense cluster completely, then a sparse
        // far-future tail forces a reseed with a very different width.
        assert_parity("reseed", |step| {
            for i in 0..500 {
                step(Some(i as f64 * 0.001));
            }
            step(Some(5.0e4));
            step(Some(9.0e7));
            for _ in 0..503 {
                step(None);
            }
            step(None); // empty pop
        });
    }

    #[test]
    fn len_and_pushed_track_operations() {
        let mut q: EventQueue<&'static str> = EventQueue::new(EventQueueKind::Wheel);
        assert!(q.is_empty());
        q.push(2.0, "b");
        q.push(1.0, "a");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pushed(), 2);
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pushed(), 2, "pushed counts lifetime pushes, not len");
    }

    #[test]
    fn kind_parse_and_labels_round_trip() {
        for kind in EventQueueKind::all() {
            assert_eq!(EventQueueKind::parse(kind.label()), Some(kind));
            assert_eq!(format!("{kind}"), kind.label());
        }
        assert_eq!(EventQueueKind::parse("calendar"), Some(EventQueueKind::Wheel));
        assert_eq!(EventQueueKind::parse("binary-heap"), Some(EventQueueKind::Heap));
        assert_eq!(EventQueueKind::parse("bogus"), None);
        assert_eq!(EventQueueKind::default(), EventQueueKind::Wheel);
    }
}
