//! Pluggable load balancers fronting the sharded server fleet.
//!
//! The fleet simulator ([`crate::sim::fleet`]) models the server side as
//! K *shards* — replicas with their own admission slots and FIFO queue.
//! A [`Balancer`] decides, at arrival time, which shard a server-bound
//! request joins. The balancer sees only a [`ShardView`] snapshot per
//! shard (live queue length, slots in use, outstanding work estimate,
//! and whether the shard is admitting new work); it never inspects
//! requests, so policies stay O(K) and the per-request RNG streams are
//! untouched (randomized balancers draw from a dedicated fleet-level
//! stream). Under autoscaling, cold (still loading) and draining
//! (scale-in victim) shards are flagged non-admitting: every balancer
//! skips them while at least one admitting shard exists, and degrades to
//! ranking the full set — never panicking — when none does.
//!
//! Implementations:
//!
//! * [`RoundRobin`] — cycle through shards in index order; oblivious to
//!   load, the classic DNS/LVS baseline.
//! * [`JoinShortestQueue`] — join the shard with the fewest outstanding
//!   requests (running + queued); ties break to the lowest index.
//! * [`PowerOfTwoChoices`] — sample two distinct shards uniformly and
//!   join the less loaded one: near-JSQ tails at O(1) state inspection
//!   (Mitzenmacher's classic result).
//! * [`LeastWork`] — join the shard with the least outstanding
//!   *estimated service seconds* rather than request count; exploits the
//!   simulator's pre-drawn prefill samples as a size oracle.

use crate::util::rng::Rng;

/// Balancer-visible snapshot of one shard at decision time.
#[derive(Clone, Copy, Debug)]
pub struct ShardView {
    /// Requests currently in service on the shard (holding a slot, or
    /// simply admitted when the pool is unlimited).
    pub in_use: usize,
    /// Live (non-cancelled) requests waiting in the shard's FIFO queue.
    pub queued: usize,
    /// Concurrent-admission cap (`None` = unlimited).
    pub slots: Option<usize>,
    /// Outstanding estimated service seconds assigned to the shard:
    /// pre-drawn prefill samples of requests queued or currently in
    /// service (retired when the slot frees).
    pub work: f64,
    /// Prompt tokens of the live queued entries — the admission-backlog
    /// signal under continuous batching, where `slots` is `None` and
    /// the token budget (not a slot count) gates admission. Balancers
    /// and the autoscaler read backlog in tokens there; always
    /// maintained (0 on an empty queue) so slot fleets surface it too.
    pub queued_tokens: u64,
    /// Whether the shard accepts new work. Cold (still loading),
    /// draining (scale-in victim), and retired shards are not admitting;
    /// every balancer must skip them while any admitting shard exists.
    pub admitting: bool,
}

impl ShardView {
    /// Total outstanding requests on the shard (running + queued).
    pub fn outstanding(&self) -> usize {
        self.in_use + self.queued
    }
}

/// A shard-selection policy. `pick` must return an index in
/// `0..shards.len()` (`shards` is never empty), and must return an
/// *admitting* shard whenever at least one exists. When no shard admits
/// (every replica cold or draining — the autoscaled fleet prevents this
/// by construction, but defensive callers may not), implementations fall
/// back to ranking every shard instead of panicking.
pub trait Balancer {
    fn name(&self) -> &'static str;

    /// Choose the shard an arriving server-bound request joins. `rng` is
    /// the fleet-level balancer stream (seeded from `SimConfig.seed`,
    /// disjoint from every per-request stream), so randomized policies
    /// stay deterministic without perturbing request trajectories.
    fn pick(&mut self, shards: &[ShardView], rng: &mut Rng) -> usize;
}

/// Index minimizing `better` over admitting shards (ties keep the lowest
/// index); over *all* shards when none admits (degraded fallback — never
/// panics on a non-empty slice). `pub(crate)` so the fleet's debug-mode
/// parity assert can check [`ShardIndex`] picks against the linear scan.
pub(crate) fn argmin_admitting(
    shards: &[ShardView],
    better: impl Fn(&ShardView, &ShardView) -> bool,
) -> usize {
    let mut best: Option<usize> = None;
    for (i, s) in shards.iter().enumerate() {
        if !s.admitting {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) if better(s, &shards[b]) => best = Some(i),
            _ => {}
        }
    }
    best.unwrap_or_else(|| {
        let mut b = 0;
        for (i, s) in shards.iter().enumerate().skip(1) {
            if better(s, &shards[b]) {
                b = i;
            }
        }
        b
    })
}

/// Pick the shard a §4.3 migrating stream re-prefills on (and the shard
/// an outage victim re-queues to): **least-work-with-estimate** — the
/// admitting shard minimizing `outstanding work + extra(i)`, where
/// `extra` is the caller's per-shard cost estimate: the shard's RTT
/// offset plus its predicted admission delay — seconds of queued-ahead
/// slot work under the legacy slot pools, or the queued **prompt-token
/// backlog over the admission token rate** under continuous batching
/// (the fleet's `reprefill_queue_delay` builds it either way). Ties
/// break to the lowest index.
///
/// Unlike [`Balancer::pick`], this returns `None` when **no** shard
/// admits (every replica cold, draining, or retired): a migrating stream
/// must never be routed onto a dying shard, so the caller falls back to
/// the base endpoint instead. Deterministic — consumes no randomness —
/// so invoking it at resolve time never perturbs the fleet-level
/// balancer stream.
pub fn pick_reprefill_target(
    shards: &[ShardView],
    extra: impl Fn(usize) -> f64,
) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, s) in shards.iter().enumerate() {
        if !s.admitting {
            continue;
        }
        let score = s.work + extra(i);
        let better = match best {
            None => true,
            Some((_, b)) => score.total_cmp(&b) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// Selector for a [`Balancer`] implementation; the experiment grids and
/// CLI flags carry this (Copy) tag rather than boxed trait objects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalancerKind {
    RoundRobin,
    JoinShortestQueue,
    PowerOfTwoChoices,
    LeastWork,
}

impl BalancerKind {
    /// All kinds, in the order the sweep grids report them.
    pub fn all() -> Vec<BalancerKind> {
        vec![
            BalancerKind::RoundRobin,
            BalancerKind::JoinShortestQueue,
            BalancerKind::PowerOfTwoChoices,
            BalancerKind::LeastWork,
        ]
    }

    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            BalancerKind::RoundRobin => "rr",
            BalancerKind::JoinShortestQueue => "jsq",
            BalancerKind::PowerOfTwoChoices => "p2c",
            BalancerKind::LeastWork => "least-work",
        }
    }

    /// Parse a CLI spelling (`rr`, `jsq`, `p2c`, `least-work`, plus
    /// long-form aliases).
    pub fn parse(s: &str) -> Option<BalancerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => BalancerKind::RoundRobin,
            "jsq" | "join-shortest-queue" | "shortest-queue" => BalancerKind::JoinShortestQueue,
            "p2c" | "power-of-two" | "power-of-two-choices" => BalancerKind::PowerOfTwoChoices,
            "lw" | "least-work" | "leastwork" => BalancerKind::LeastWork,
            _ => return None,
        })
    }

    /// Instantiate the policy (fresh state).
    pub fn build(self) -> Box<dyn Balancer> {
        match self {
            BalancerKind::RoundRobin => Box::new(RoundRobin::default()),
            BalancerKind::JoinShortestQueue => Box::new(JoinShortestQueue),
            BalancerKind::PowerOfTwoChoices => Box::new(PowerOfTwoChoices),
            BalancerKind::LeastWork => Box::new(LeastWork),
        }
    }
}

impl std::fmt::Display for BalancerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Cycle through shards in index order, ignoring load. Non-admitting
/// shards are skipped (the cursor advances past them); with every shard
/// admitting the classic cycle is unchanged.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        let k = shards.len();
        let start = self.next % k;
        // First admitting shard at or after the cursor; a full fruitless
        // cycle (no admitting shard anywhere) degrades to the cursor.
        let mut s = start;
        for off in 0..k {
            let c = (start + off) % k;
            if shards[c].admitting {
                s = c;
                break;
            }
        }
        self.next = (s + 1) % k;
        s
    }
}

/// Join the admitting shard with the fewest outstanding requests
/// (running + queued); ties break to the lowest index.
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl Balancer for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        argmin_admitting(shards, |a, b| a.outstanding() < b.outstanding())
    }
}

/// Sample two distinct *admitting* shards uniformly; join the less
/// loaded (ties to the lower index). With one candidate it degenerates
/// to that shard without consuming randomness, preserving K=1 replay
/// parity.
#[derive(Debug, Default)]
pub struct PowerOfTwoChoices;

impl PowerOfTwoChoices {
    /// Index of the `n`-th candidate (admitting shard, or any shard in
    /// the all-cold fallback).
    fn nth_candidate(shards: &[ShardView], n: usize, all: bool) -> usize {
        let mut seen = 0;
        for (i, s) in shards.iter().enumerate() {
            if all || s.admitting {
                if seen == n {
                    return i;
                }
                seen += 1;
            }
        }
        unreachable!("candidate index {n} out of range");
    }
}

impl Balancer for PowerOfTwoChoices {
    fn name(&self) -> &'static str {
        "p2c"
    }

    fn pick(&mut self, shards: &[ShardView], rng: &mut Rng) -> usize {
        if shards.len() == 1 {
            return 0;
        }
        let mut m = shards.iter().filter(|s| s.admitting).count();
        // Degraded fallback: nothing admits, sample over every shard.
        let all = m == 0;
        if all {
            m = shards.len();
        }
        if m == 1 {
            return Self::nth_candidate(shards, 0, all);
        }
        let a = rng.below(m as u64) as usize;
        let mut b = rng.below(m as u64 - 1) as usize;
        if b >= a {
            b += 1; // second draw over the remaining m-1 candidates
        }
        let (a, b) = (
            Self::nth_candidate(shards, a, all),
            Self::nth_candidate(shards, b, all),
        );
        let (la, lb) = (shards[a].outstanding(), shards[b].outstanding());
        if lb < la || (lb == la && b < a) {
            b
        } else {
            a
        }
    }
}

/// Join the admitting shard with the least outstanding estimated service
/// seconds (size-aware JSQ); ties break to the lowest index.
#[derive(Debug, Default)]
pub struct LeastWork;

impl Balancer for LeastWork {
    fn name(&self) -> &'static str {
        "least-work"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        argmin_admitting(shards, |a, b| a.work.total_cmp(&b.work) == std::cmp::Ordering::Less)
    }
}

/// One tournament-tree node: the winning shard of a subtree, with the
/// admission flag and sort key it won on.
#[derive(Clone, Copy, Debug)]
pub(crate) struct IndexNode {
    /// Whether the winning shard admits new work.
    pub admitting: bool,
    /// The winner's sort key (outstanding count as f64 for JSQ,
    /// outstanding work seconds for least-work).
    pub key: f64,
    /// The winning shard's index (`usize::MAX` on padding subtrees).
    pub shard: usize,
}

const PAD: IndexNode = IndexNode {
    admitting: false,
    key: f64::INFINITY,
    shard: usize::MAX,
};

/// Tournament winner of two sibling subtrees. `a` is the left subtree —
/// every shard index under it is lower than any under `b` — so returning
/// `a` on full ties reproduces the lowest-index tie-break of
/// [`argmin_admitting`]. Otherwise: an admitting subtree beats a
/// non-admitting one, then the strictly smaller key (`f64::total_cmp`)
/// wins.
fn combine(a: IndexNode, b: IndexNode) -> IndexNode {
    let b_wins = (b.admitting && !a.admitting)
        || (b.admitting == a.admitting && b.key.total_cmp(&a.key) == std::cmp::Ordering::Less);
    if b_wins {
        b
    } else {
        a
    }
}

/// Incrementally maintained shard-selection index for the deterministic
/// scan balancers (JSQ and least-work): a flat tournament tree over one
/// leaf per shard, so the fleet loop answers "which admitting shard has
/// the minimum key?" in O(1) at the root and repairs it in O(log K) per
/// changed shard, instead of rescanning all K shards on every arrival.
///
/// The fleet marks a shard dirty ([`ShardIndex::mark`]) whenever its
/// occupancy, queue, work, or lifecycle phase changes, and flushes the
/// dirty set (recomputing each leaf from live shard state via
/// [`ShardIndex::update`]) immediately before reading
/// [`ShardIndex::root`]. Because leaves are recomputed from the same
/// state a [`ShardView`] snapshot would report, and [`combine`]
/// reproduces `argmin_admitting`'s exact ordering (admitting-first, then
/// `total_cmp` on the key, ties to the lowest index), a flushed index
/// returns byte-for-byte the same pick as the linear scan — the fleet
/// asserts as much in debug builds.
///
/// Keys are `f64`; JSQ's outstanding counts convert exactly (they are
/// far below 2^53), so `total_cmp` on the converted key orders identically
/// to `usize` comparison.
#[derive(Debug)]
pub(crate) struct ShardIndex {
    /// Number of real shards; leaves `n..cap` are permanent padding.
    n: usize,
    /// Leaf capacity: `n` rounded up to a power of two (min 1).
    cap: usize,
    /// Implicit binary tree: root at `1`, leaf `i` at `cap + i`.
    tree: Vec<IndexNode>,
    /// Dirty shard ids awaiting a leaf recompute, deduplicated by `flag`.
    dirty: Vec<usize>,
    flag: Vec<bool>,
}

impl ShardIndex {
    /// Build an index over `n` shards with every real leaf dirty, so the
    /// first flush populates the tree from live shard state.
    pub fn new(n: usize) -> ShardIndex {
        let cap = n.max(1).next_power_of_two();
        ShardIndex {
            n,
            cap,
            tree: vec![PAD; 2 * cap],
            dirty: (0..n).collect(),
            flag: vec![true; n],
        }
    }

    /// Mark shard `s` as changed since the last flush (idempotent).
    pub fn mark(&mut self, s: usize) {
        if s < self.n && !self.flag[s] {
            self.flag[s] = true;
            self.dirty.push(s);
        }
    }

    /// Take one dirty shard id, if any (flush loop driver).
    pub fn pop_dirty(&mut self) -> Option<usize> {
        let s = self.dirty.pop()?;
        self.flag[s] = false;
        Some(s)
    }

    /// Recompute shard `s`'s leaf and repair the path to the root.
    pub fn update(&mut self, s: usize, admitting: bool, key: f64) {
        debug_assert!(s < self.n, "shard {s} out of range {}", self.n);
        let mut i = self.cap + s;
        self.tree[i] = IndexNode {
            admitting,
            key,
            shard: s,
        };
        while i > 1 {
            i /= 2;
            self.tree[i] = combine(self.tree[2 * i], self.tree[2 * i + 1]);
        }
    }

    /// The tournament winner over all shards. `admitting == false` means
    /// *no* shard admits (padding never wins against a real leaf, even a
    /// non-admitting one, because its key is `+inf`); callers fall back
    /// to their degraded path in that case rather than using `shard`.
    pub fn root(&self) -> IndexNode {
        self.tree[1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(in_use: usize, queued: usize, work: f64) -> ShardView {
        ShardView {
            in_use,
            queued,
            slots: Some(2),
            work,
            queued_tokens: queued as u64 * 10,
            admitting: true,
        }
    }

    fn cold(in_use: usize, queued: usize, work: f64) -> ShardView {
        ShardView {
            admitting: false,
            ..view(in_use, queued, work)
        }
    }

    /// Random shard states: JSQ must always pick a shard whose
    /// outstanding count equals the minimum (never a longer queue than
    /// the shortest available).
    #[test]
    fn jsq_never_picks_longer_than_shortest() {
        let mut rng = Rng::new(71);
        let mut jsq = JoinShortestQueue;
        for _ in 0..500 {
            let k = 2 + rng.below(7) as usize;
            let shards: Vec<ShardView> = (0..k)
                .map(|_| {
                    view(
                        rng.below(4) as usize,
                        rng.below(20) as usize,
                        rng.f64() * 10.0,
                    )
                })
                .collect();
            let pick = jsq.pick(&shards, &mut rng);
            let min = shards.iter().map(|s| s.outstanding()).min().unwrap();
            assert_eq!(
                shards[pick].outstanding(),
                min,
                "JSQ joined a longer queue: picked {pick} of {shards:?}"
            );
        }
    }

    #[test]
    fn jsq_breaks_ties_to_lowest_index() {
        let mut rng = Rng::new(1);
        let shards = vec![view(1, 2, 0.0), view(0, 3, 0.0), view(1, 2, 0.0)];
        assert_eq!(JoinShortestQueue.pick(&shards, &mut rng), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut rng = Rng::new(1);
        let mut rr = RoundRobin::default();
        let shards = vec![view(0, 0, 0.0); 3];
        let picks: Vec<usize> = (0..7).map(|_| rr.pick(&shards, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    /// P2C is a pure function of (shard states, rng stream): the same
    /// seed reproduces the same pick sequence, and picks always land on
    /// the less loaded of the two sampled shards.
    #[test]
    fn p2c_deterministic_and_prefers_less_loaded() {
        let shards = vec![view(2, 8, 0.0), view(0, 0, 0.0), view(1, 3, 0.0), view(2, 9, 0.0)];
        let run = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            let mut p2c = PowerOfTwoChoices;
            (0..64).map(|_| p2c.pick(&shards, &mut rng)).collect()
        };
        assert_eq!(run(9), run(9), "fixed seed must reproduce picks");
        assert_ne!(run(9), run(10), "different seeds should explore differently");
        // The globally most-loaded shard (index 3) is only picked when
        // both samples land on it — with 4 shards that is rare; shard 1
        // (empty) must dominate.
        let picks = run(9);
        let c1 = picks.iter().filter(|&&p| p == 1).count();
        let c3 = picks.iter().filter(|&&p| p == 3).count();
        assert!(c1 > c3, "empty shard picked {c1}x vs most-loaded {c3}x");
    }

    #[test]
    fn p2c_single_shard_consumes_no_randomness() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let shards = vec![view(3, 3, 0.0)];
        assert_eq!(PowerOfTwoChoices.pick(&shards, &mut a), 0);
        assert_eq!(a.next_u64(), b.next_u64(), "rng must be untouched");
    }

    #[test]
    fn least_work_picks_minimum_work() {
        let mut rng = Rng::new(2);
        let shards = vec![view(0, 9, 1.5), view(5, 0, 0.25), view(1, 1, 3.0)];
        assert_eq!(LeastWork.pick(&shards, &mut rng), 1);
    }

    /// Every balancer must skip cold/draining shards while an admitting
    /// one exists — even when the non-admitting shard looks (or is)
    /// emptier.
    #[test]
    fn balancers_skip_non_admitting_shards() {
        let shards = vec![
            cold(0, 0, 0.0), // emptiest, but not admitting
            view(2, 5, 6.0),
            view(1, 1, 2.0),
            cold(0, 0, 0.0),
        ];
        let mut rng = Rng::new(31);
        assert_eq!(JoinShortestQueue.pick(&shards, &mut rng), 2);
        assert_eq!(LeastWork.pick(&shards, &mut rng), 2);
        let mut rr = RoundRobin::default();
        // The cursor starts at 0 (cold) and must land on admitting
        // shards only, cycling 1, 2, 1, 2, …
        let picks: Vec<usize> = (0..4).map(|_| rr.pick(&shards, &mut rng)).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
        for _ in 0..200 {
            let p = PowerOfTwoChoices.pick(&shards, &mut rng);
            assert!(shards[p].admitting, "p2c picked non-admitting shard {p}");
        }
    }

    /// Degraded fallback: when *no* shard admits (every replica cold or
    /// draining), balancers must not panic and must return a valid index.
    #[test]
    fn balancers_survive_all_cold_fleet() {
        let shards = vec![cold(1, 4, 5.0), cold(0, 2, 1.0), cold(3, 0, 9.0)];
        let mut rng = Rng::new(32);
        // JSQ/least-work fall back to ranking everything.
        assert_eq!(JoinShortestQueue.pick(&shards, &mut rng), 1);
        assert_eq!(LeastWork.pick(&shards, &mut rng), 1);
        let mut rr = RoundRobin::default();
        for want in [0, 1, 2, 0] {
            assert_eq!(rr.pick(&shards, &mut rng), want);
        }
        for _ in 0..100 {
            let p = PowerOfTwoChoices.pick(&shards, &mut rng);
            assert!(p < shards.len());
        }
        // Single all-cold shard: still index 0, no panic.
        let one = vec![cold(0, 7, 3.0)];
        assert_eq!(JoinShortestQueue.pick(&one, &mut rng), 0);
        assert_eq!(PowerOfTwoChoices.pick(&one, &mut rng), 0);
        assert_eq!(RoundRobin::default().pick(&one, &mut rng), 0);
        assert_eq!(LeastWork.pick(&one, &mut rng), 0);
    }

    /// With exactly one admitting shard among many, P2C returns it
    /// without consuming randomness (the single-candidate degeneration).
    #[test]
    fn p2c_single_admitting_candidate_consumes_no_randomness() {
        let shards = vec![cold(0, 0, 0.0), view(3, 3, 4.0), cold(1, 1, 1.0)];
        let mut a = Rng::new(33);
        let mut b = Rng::new(33);
        assert_eq!(PowerOfTwoChoices.pick(&shards, &mut a), 1);
        assert_eq!(a.next_u64(), b.next_u64(), "rng must be untouched");
    }

    /// Shard-targeted re-prefill never selects a non-admitting shard —
    /// even when the cold/draining shard is the emptiest — and the
    /// estimate term can override raw outstanding work.
    #[test]
    fn reprefill_target_skips_non_admitting_and_weighs_estimate() {
        let shards = vec![
            cold(0, 0, 0.0), // emptiest, but cold: must never be picked
            view(2, 5, 6.0),
            view(1, 1, 2.0),
        ];
        assert_eq!(pick_reprefill_target(&shards, |_| 0.0), Some(2));
        // A large per-shard estimate (e.g. cross-region RTT) flips the
        // choice to the busier-but-closer shard.
        assert_eq!(
            pick_reprefill_target(&shards, |i| if i == 2 { 10.0 } else { 0.0 }),
            Some(1)
        );
        // Exact ties break to the lowest admitting index.
        let tied = vec![cold(0, 0, 1.0), view(0, 0, 1.0), view(0, 0, 1.0)];
        assert_eq!(pick_reprefill_target(&tied, |_| 0.0), Some(1));
        // Randomized sweep: the pick is always admitting, never panics.
        let mut rng = Rng::new(77);
        for _ in 0..300 {
            let k = 1 + rng.below(6) as usize;
            let shards: Vec<ShardView> = (0..k)
                .map(|_| {
                    let v = view(
                        rng.below(4) as usize,
                        rng.below(9) as usize,
                        rng.f64() * 8.0,
                    );
                    if rng.chance(0.4) {
                        ShardView {
                            admitting: false,
                            ..v
                        }
                    } else {
                        v
                    }
                })
                .collect();
            match pick_reprefill_target(&shards, |i| i as f64 * 0.01) {
                Some(p) => assert!(shards[p].admitting, "picked non-admitting {p}"),
                None => assert!(shards.iter().all(|s| !s.admitting)),
            }
        }
    }

    /// Token-priced targeting (continuous batching): a shard with less
    /// outstanding work but a deep queued-token backlog loses the pick
    /// once the backlog is priced into `extra` — the admission delay a
    /// migrating re-prefill would actually pay at the token gate.
    #[test]
    fn reprefill_target_prices_token_backlog() {
        let mut shards = vec![view(2, 0, 1.0), view(2, 6, 1.5)];
        shards[0].queued_tokens = 4000; // deep prefill backlog
        shards[1].queued_tokens = 0;
        // Unpriced, shard 0 wins on raw work…
        assert_eq!(pick_reprefill_target(&shards, |_| 0.0), Some(0));
        // …but at 512 tokens/s its backlog is ~7.8 s of admission delay.
        let tokens_per_sec = 512.0;
        assert_eq!(
            pick_reprefill_target(&shards, |i| shards[i].queued_tokens as f64 / tokens_per_sec),
            Some(1)
        );
    }

    /// The all-cold/draining fallback returns `None` (the caller falls
    /// back to the base endpoint) instead of panicking — including the
    /// empty-fleet degenerate.
    #[test]
    fn reprefill_target_all_cold_is_none_not_panic() {
        let shards = vec![cold(1, 4, 5.0), cold(0, 2, 1.0)];
        assert_eq!(pick_reprefill_target(&shards, |_| 0.0), None);
        assert_eq!(pick_reprefill_target(&[], |_| 0.0), None);
    }

    /// Drive a [`ShardIndex`] and the linear `argmin_admitting` scan
    /// through the same randomized mutation stream: after every flush the
    /// root must name exactly the shard the scan balancer would pick,
    /// for both the JSQ key (outstanding as f64) and the least-work key.
    #[test]
    fn shard_index_matches_linear_scan_under_random_mutations() {
        let mut rng = Rng::new(0xD15C);
        for trial in 0..200 {
            let k = 1 + rng.below(9) as usize;
            let mut shards: Vec<ShardView> = (0..k)
                .map(|_| {
                    let v = view(
                        rng.below(4) as usize,
                        rng.below(12) as usize,
                        // Quantized so exact key ties are common.
                        rng.below(5) as f64 * 0.5,
                    );
                    ShardView {
                        admitting: !rng.chance(0.3),
                        ..v
                    }
                })
                .collect();
            let mut jsq_idx = ShardIndex::new(k);
            let mut lw_idx = ShardIndex::new(k);
            for step in 0..40 {
                // Mutate a random shard (after the first pass, which
                // flushes the initial all-dirty state unchanged).
                if step > 0 {
                    let s = rng.below(k as u64) as usize;
                    shards[s] = ShardView {
                        admitting: !rng.chance(0.3),
                        ..view(
                            rng.below(4) as usize,
                            rng.below(12) as usize,
                            rng.below(5) as f64 * 0.5,
                        )
                    };
                    jsq_idx.mark(s);
                    jsq_idx.mark(s); // idempotent double-mark
                    lw_idx.mark(s);
                }
                while let Some(s) = jsq_idx.pop_dirty() {
                    jsq_idx.update(s, shards[s].admitting, shards[s].outstanding() as f64);
                }
                while let Some(s) = lw_idx.pop_dirty() {
                    lw_idx.update(s, shards[s].admitting, shards[s].work);
                }
                let any = shards.iter().any(|s| s.admitting);
                let (jr, lr) = (jsq_idx.root(), lw_idx.root());
                assert_eq!(jr.admitting, any, "trial {trial} step {step}: {shards:?}");
                assert_eq!(lr.admitting, any, "trial {trial} step {step}: {shards:?}");
                if any {
                    let want_jsq =
                        argmin_admitting(&shards, |a, b| a.outstanding() < b.outstanding());
                    let want_lw = argmin_admitting(&shards, |a, b| {
                        a.work.total_cmp(&b.work) == std::cmp::Ordering::Less
                    });
                    assert_eq!(
                        jr.shard, want_jsq,
                        "trial {trial} step {step} JSQ: {shards:?}"
                    );
                    assert_eq!(
                        lr.shard, want_lw,
                        "trial {trial} step {step} least-work: {shards:?}"
                    );
                }
            }
        }
    }

    /// Exact key ties resolve to the lowest shard index, matching the
    /// scan balancers, including across power-of-two subtree boundaries.
    #[test]
    fn shard_index_breaks_ties_to_lowest_index() {
        for k in [2usize, 3, 5, 8] {
            let mut idx = ShardIndex::new(k);
            while let Some(s) = idx.pop_dirty() {
                idx.update(s, true, 7.0);
            }
            assert_eq!(idx.root().shard, 0, "k={k}: all-tied must pick shard 0");
            // Lower key on the last shard wins; re-tie returns to 0.
            idx.update(k - 1, true, 3.0);
            assert_eq!(idx.root().shard, k - 1);
            idx.update(k - 1, true, 7.0);
            assert_eq!(idx.root().shard, 0);
        }
    }

    /// With no admitting shard, the root reports `admitting == false`
    /// (the fleet's cue to take its degraded path) — padding leaves never
    /// masquerade as real shards.
    #[test]
    fn shard_index_all_cold_root_reports_non_admitting() {
        let mut idx = ShardIndex::new(3);
        while let Some(s) = idx.pop_dirty() {
            idx.update(s, false, s as f64);
        }
        let root = idx.root();
        assert!(!root.admitting);
        assert!(root.shard < 3, "winner must still be a real shard");
        // One shard warms up: it wins regardless of key.
        idx.update(2, true, 1e9);
        assert!(idx.root().admitting);
        assert_eq!(idx.root().shard, 2);
    }

    #[test]
    fn kind_roundtrips_labels() {
        for kind in BalancerKind::all() {
            assert_eq!(BalancerKind::parse(kind.label()), Some(kind));
            assert_eq!(kind.build().name(), kind.label());
            assert_eq!(kind.to_string(), kind.label());
        }
        assert_eq!(BalancerKind::parse("round-robin"), Some(BalancerKind::RoundRobin));
        assert_eq!(BalancerKind::parse("lw"), Some(BalancerKind::LeastWork));
        assert!(BalancerKind::parse("nope").is_none());
    }
}
