//! Pluggable load balancers fronting the sharded server fleet.
//!
//! The fleet simulator ([`crate::sim::fleet`]) models the server side as
//! K *shards* — replicas with their own admission slots and FIFO queue.
//! A [`Balancer`] decides, at arrival time, which shard a server-bound
//! request joins. The balancer sees only a [`ShardView`] snapshot per
//! shard (live queue length, slots in use, outstanding work estimate);
//! it never inspects requests, so policies stay O(K) and the per-request
//! RNG streams are untouched (randomized balancers draw from a dedicated
//! fleet-level stream).
//!
//! Implementations:
//!
//! * [`RoundRobin`] — cycle through shards in index order; oblivious to
//!   load, the classic DNS/LVS baseline.
//! * [`JoinShortestQueue`] — join the shard with the fewest outstanding
//!   requests (running + queued); ties break to the lowest index.
//! * [`PowerOfTwoChoices`] — sample two distinct shards uniformly and
//!   join the less loaded one: near-JSQ tails at O(1) state inspection
//!   (Mitzenmacher's classic result).
//! * [`LeastWork`] — join the shard with the least outstanding
//!   *estimated service seconds* rather than request count; exploits the
//!   simulator's pre-drawn prefill samples as a size oracle.

use crate::util::rng::Rng;

/// Balancer-visible snapshot of one shard at decision time.
#[derive(Clone, Copy, Debug)]
pub struct ShardView {
    /// Requests currently in service on the shard (holding a slot, or
    /// simply admitted when the pool is unlimited).
    pub in_use: usize,
    /// Live (non-cancelled) requests waiting in the shard's FIFO queue.
    pub queued: usize,
    /// Concurrent-admission cap (`None` = unlimited).
    pub slots: Option<usize>,
    /// Outstanding estimated service seconds assigned to the shard:
    /// pre-drawn prefill samples of requests queued or currently in
    /// service (retired when the slot frees).
    pub work: f64,
}

impl ShardView {
    /// Total outstanding requests on the shard (running + queued).
    pub fn outstanding(&self) -> usize {
        self.in_use + self.queued
    }
}

/// A shard-selection policy. `pick` must return an index in
/// `0..shards.len()` (`shards` is never empty).
pub trait Balancer {
    fn name(&self) -> &'static str;

    /// Choose the shard an arriving server-bound request joins. `rng` is
    /// the fleet-level balancer stream (seeded from `SimConfig.seed`,
    /// disjoint from every per-request stream), so randomized policies
    /// stay deterministic without perturbing request trajectories.
    fn pick(&mut self, shards: &[ShardView], rng: &mut Rng) -> usize;
}

/// Selector for a [`Balancer`] implementation; the experiment grids and
/// CLI flags carry this (Copy) tag rather than boxed trait objects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalancerKind {
    RoundRobin,
    JoinShortestQueue,
    PowerOfTwoChoices,
    LeastWork,
}

impl BalancerKind {
    /// All kinds, in the order the sweep grids report them.
    pub fn all() -> Vec<BalancerKind> {
        vec![
            BalancerKind::RoundRobin,
            BalancerKind::JoinShortestQueue,
            BalancerKind::PowerOfTwoChoices,
            BalancerKind::LeastWork,
        ]
    }

    /// Short label used in tables, CSVs, and CLI flags.
    pub fn label(&self) -> &'static str {
        match self {
            BalancerKind::RoundRobin => "rr",
            BalancerKind::JoinShortestQueue => "jsq",
            BalancerKind::PowerOfTwoChoices => "p2c",
            BalancerKind::LeastWork => "least-work",
        }
    }

    /// Parse a CLI spelling (`rr`, `jsq`, `p2c`, `least-work`, plus
    /// long-form aliases).
    pub fn parse(s: &str) -> Option<BalancerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => BalancerKind::RoundRobin,
            "jsq" | "join-shortest-queue" | "shortest-queue" => BalancerKind::JoinShortestQueue,
            "p2c" | "power-of-two" | "power-of-two-choices" => BalancerKind::PowerOfTwoChoices,
            "lw" | "least-work" | "leastwork" => BalancerKind::LeastWork,
            _ => return None,
        })
    }

    /// Instantiate the policy (fresh state).
    pub fn build(self) -> Box<dyn Balancer> {
        match self {
            BalancerKind::RoundRobin => Box::new(RoundRobin::default()),
            BalancerKind::JoinShortestQueue => Box::new(JoinShortestQueue),
            BalancerKind::PowerOfTwoChoices => Box::new(PowerOfTwoChoices),
            BalancerKind::LeastWork => Box::new(LeastWork),
        }
    }
}

impl std::fmt::Display for BalancerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Cycle through shards in index order, ignoring load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Balancer for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        let s = self.next % shards.len();
        self.next = (s + 1) % shards.len();
        s
    }
}

/// Join the shard with the fewest outstanding requests (running +
/// queued); ties break to the lowest index.
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl Balancer for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        let mut best = 0;
        for (i, s) in shards.iter().enumerate().skip(1) {
            if s.outstanding() < shards[best].outstanding() {
                best = i;
            }
        }
        best
    }
}

/// Sample two distinct shards uniformly; join the less loaded (ties to
/// the lower index). With one shard it degenerates to that shard without
/// consuming randomness.
#[derive(Debug, Default)]
pub struct PowerOfTwoChoices;

impl Balancer for PowerOfTwoChoices {
    fn name(&self) -> &'static str {
        "p2c"
    }

    fn pick(&mut self, shards: &[ShardView], rng: &mut Rng) -> usize {
        let k = shards.len();
        if k == 1 {
            return 0;
        }
        let a = rng.below(k as u64) as usize;
        let mut b = rng.below(k as u64 - 1) as usize;
        if b >= a {
            b += 1; // second draw over the remaining k-1 shards
        }
        let (la, lb) = (shards[a].outstanding(), shards[b].outstanding());
        if lb < la || (lb == la && b < a) {
            b
        } else {
            a
        }
    }
}

/// Join the shard with the least outstanding estimated service seconds
/// (size-aware JSQ); ties break to the lowest index.
#[derive(Debug, Default)]
pub struct LeastWork;

impl Balancer for LeastWork {
    fn name(&self) -> &'static str {
        "least-work"
    }

    fn pick(&mut self, shards: &[ShardView], _rng: &mut Rng) -> usize {
        let mut best = 0;
        for (i, s) in shards.iter().enumerate().skip(1) {
            if s.work.total_cmp(&shards[best].work) == std::cmp::Ordering::Less {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(in_use: usize, queued: usize, work: f64) -> ShardView {
        ShardView {
            in_use,
            queued,
            slots: Some(2),
            work,
        }
    }

    /// Random shard states: JSQ must always pick a shard whose
    /// outstanding count equals the minimum (never a longer queue than
    /// the shortest available).
    #[test]
    fn jsq_never_picks_longer_than_shortest() {
        let mut rng = Rng::new(71);
        let mut jsq = JoinShortestQueue;
        for _ in 0..500 {
            let k = 2 + rng.below(7) as usize;
            let shards: Vec<ShardView> = (0..k)
                .map(|_| {
                    view(
                        rng.below(4) as usize,
                        rng.below(20) as usize,
                        rng.f64() * 10.0,
                    )
                })
                .collect();
            let pick = jsq.pick(&shards, &mut rng);
            let min = shards.iter().map(|s| s.outstanding()).min().unwrap();
            assert_eq!(
                shards[pick].outstanding(),
                min,
                "JSQ joined a longer queue: picked {pick} of {shards:?}"
            );
        }
    }

    #[test]
    fn jsq_breaks_ties_to_lowest_index() {
        let mut rng = Rng::new(1);
        let shards = vec![view(1, 2, 0.0), view(0, 3, 0.0), view(1, 2, 0.0)];
        assert_eq!(JoinShortestQueue.pick(&shards, &mut rng), 0);
    }

    #[test]
    fn round_robin_cycles() {
        let mut rng = Rng::new(1);
        let mut rr = RoundRobin::default();
        let shards = vec![view(0, 0, 0.0); 3];
        let picks: Vec<usize> = (0..7).map(|_| rr.pick(&shards, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    /// P2C is a pure function of (shard states, rng stream): the same
    /// seed reproduces the same pick sequence, and picks always land on
    /// the less loaded of the two sampled shards.
    #[test]
    fn p2c_deterministic_and_prefers_less_loaded() {
        let shards = vec![view(2, 8, 0.0), view(0, 0, 0.0), view(1, 3, 0.0), view(2, 9, 0.0)];
        let run = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            let mut p2c = PowerOfTwoChoices;
            (0..64).map(|_| p2c.pick(&shards, &mut rng)).collect()
        };
        assert_eq!(run(9), run(9), "fixed seed must reproduce picks");
        assert_ne!(run(9), run(10), "different seeds should explore differently");
        // The globally most-loaded shard (index 3) is only picked when
        // both samples land on it — with 4 shards that is rare; shard 1
        // (empty) must dominate.
        let picks = run(9);
        let c1 = picks.iter().filter(|&&p| p == 1).count();
        let c3 = picks.iter().filter(|&&p| p == 3).count();
        assert!(c1 > c3, "empty shard picked {c1}x vs most-loaded {c3}x");
    }

    #[test]
    fn p2c_single_shard_consumes_no_randomness() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let shards = vec![view(3, 3, 0.0)];
        assert_eq!(PowerOfTwoChoices.pick(&shards, &mut a), 0);
        assert_eq!(a.next_u64(), b.next_u64(), "rng must be untouched");
    }

    #[test]
    fn least_work_picks_minimum_work() {
        let mut rng = Rng::new(2);
        let shards = vec![view(0, 9, 1.5), view(5, 0, 0.25), view(1, 1, 3.0)];
        assert_eq!(LeastWork.pick(&shards, &mut rng), 1);
    }

    #[test]
    fn kind_roundtrips_labels() {
        for kind in BalancerKind::all() {
            assert_eq!(BalancerKind::parse(kind.label()), Some(kind));
            assert_eq!(kind.build().name(), kind.label());
            assert_eq!(kind.to_string(), kind.label());
        }
        assert_eq!(BalancerKind::parse("round-robin"), Some(BalancerKind::RoundRobin));
        assert_eq!(BalancerKind::parse("lw"), Some(BalancerKind::LeastWork));
        assert!(BalancerKind::parse("nope").is_none());
    }
}
