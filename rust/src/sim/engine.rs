//! The workload simulation engine.
//!
//! Replays a trace against a (server, device) endpoint pair under a
//! dispatch policy, reproducing the paper's evaluation methodology: the
//! prefill race between endpoints, loser cancellation, token-level
//! migration with buffered handoff, consumption-rate delivery smoothing,
//! unified cost metering, and single-flight device occupancy.
//!
//! Since the fleet refactor there is **one** request code path: the
//! per-request trajectory lives in `resolve_request`, parameterized by
//! the absolute times at which the contended resources (a server shard's
//! admission slot, the single-flight device) were granted.
//! [`Scenario::run`] is the degenerate case of the discrete-event loop in
//! [`crate::sim::fleet`] with one unlimited server shard — exactly the
//! paper's independent-replay methodology — while finite sharded fleets
//! surface queueing and load-balancing effects.

use crate::coordinator::dispatch::Decision;
use crate::coordinator::migration::{MigrationConfig, MigrationPlanner};
use crate::coordinator::policy::Policy;
use crate::cost::unified::{Constraint, CostMeter, CostParams};
use crate::endpoint::{DeviceEndpoint, EndpointKind, ServerEndpoint, SimEndpoint};
use crate::metrics::{FleetReport, Report, RequestRecord};
use crate::profiles::{DeviceProfile, ServerProfile};
use crate::sim::delivery;
use crate::sim::fleet::{self, FleetConfig, FleetOutcome};
use crate::stats::ecdf::Ecdf;
use crate::trace::{Request, Trace};
use crate::util::rng::Rng;

/// Simulation-wide configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Serving-side generation length limit (Appendix E: 128).
    pub gen_limit: u32,
    /// Migration controller settings (consumption rate, RTT).
    pub migration: MigrationConfig,
    /// Base seed; combined with a per-request fork.
    pub seed: u64,
    /// Model single-flight device occupancy across requests. The paper's
    /// evaluation replays trace requests independently (per-request
    /// latencies sampled from the measured distributions), so this is
    /// OFF by default; enable it to study queueing effects at high
    /// arrival rates (see the `device_occupancy` tests and Fig 5's
    /// activity-level sweep).
    pub device_queueing: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            gen_limit: 128,
            migration: MigrationConfig::default(),
            seed: 0,
            device_queueing: false,
        }
    }
}

/// One evaluation scenario: a service trace model, a device configuration,
/// and the unified cost parameters.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub server: ServerEndpoint,
    pub device: DeviceEndpoint,
    pub costs: CostParams,
    pub cfg: SimConfig,
}

/// Exchange rates λ ($ per PFLOP) calibrated per Appendix E so each
/// scenario is internally consistent (see DESIGN.md §Substitutions: the
/// paper's "per million FLOPs" unit is taken as per 10⁹ MFLOPs, the only
/// reading under which both constraint regimes arise with Table 8 prices).
pub const LAMBDA_SERVER_CONSTRAINED: f64 = 0.1;
pub const LAMBDA_DEVICE_CONSTRAINED: f64 = 5.0;

impl Scenario {
    /// Build a scenario for the given constraint regime.
    pub fn new(
        server: ServerProfile,
        device: DeviceProfile,
        constraint: Constraint,
        cfg: SimConfig,
    ) -> Scenario {
        let lambda = match constraint {
            Constraint::Server => LAMBDA_SERVER_CONSTRAINED,
            Constraint::Device => LAMBDA_DEVICE_CONSTRAINED,
        };
        // λ is $ / PFLOP: convert via FLOPs-per-token / 1e15 × λ·1e9 ≡
        // (FLOPs/1e6) × (λ·1e-9) in the CostParams MFLOP interface.
        let costs = CostParams::from_profiles(
            &server.pricing,
            &device.arch,
            lambda * 1e-9,
            cfg.gen_limit,
        );
        Scenario {
            server: ServerEndpoint::new(server),
            device: DeviceEndpoint::new(device),
            costs,
            cfg,
        }
    }

    /// Profile the server TTFT distribution (what a deployed client
    /// gathers before planning — §4.2 "obtained either from
    /// server-provided information or device-side profiling").
    pub fn profile_server_ttft(&self, n: usize, seed: u64) -> Ecdf {
        let mut rng = Rng::new(seed ^ 0x5E4E4);
        Ecdf::new(
            (0..n)
                .map(|_| self.server.profile.sample_ttft(&mut rng))
                .collect(),
        )
    }

    /// Run a trace under a policy; returns per-request records.
    ///
    /// This is the fleet loop's degenerate configuration: one server
    /// shard with unlimited admission (the paper's independent replay),
    /// device single-flight per `cfg.device_queueing`.
    pub fn run(&self, trace: &Trace, policy: &Policy) -> Vec<RequestRecord> {
        self.run_fleet(trace, policy, &FleetConfig::replay(self.cfg.device_queueing))
            .records
    }

    /// Run and aggregate.
    pub fn run_report(&self, trace: &Trace, policy: &Policy) -> Report {
        let records = self.run(trace, policy);
        Report::from_records(&records, policy.constraint())
    }

    /// Run under an explicit fleet configuration (finite server pool,
    /// admission queueing); returns records plus load metrics.
    pub fn run_fleet(&self, trace: &Trace, policy: &Policy, fleet: &FleetConfig) -> FleetOutcome {
        fleet::run_fleet(self, trace, policy, fleet)
    }

    /// Run a zone-partitioned fleet: Z independent zones on scoped
    /// worker threads, merged bit-reproducibly (`sim/zones.rs`). A
    /// single-zone config is byte-identical to [`Self::run_fleet`].
    pub fn run_zoned_fleet(
        &self,
        trace: &Trace,
        policy: &Policy,
        zoned: &crate::sim::zones::ZonedFleetConfig,
    ) -> crate::sim::zones::ZonedOutcome {
        crate::sim::zones::run_zoned_fleet(self, trace, policy, zoned)
    }

    /// Run a fleet configuration and aggregate QoE + load metrics.
    pub fn run_fleet_report(
        &self,
        trace: &Trace,
        policy: &Policy,
        fleet: &FleetConfig,
    ) -> FleetReport {
        let out = self.run_fleet(trace, policy, fleet);
        FleetReport {
            qoe: Report::from_records(&out.records, policy.constraint()),
            load: out.load,
        }
    }
}

/// Consumed-token count at absolute time `t` for a stream whose first
/// token appeared at `ttft` (ideal pacing at `r_c`).
fn consumed_at(t: f64, ttft: f64, r_c: f64, n: u32) -> u32 {
    if t < ttft {
        return 0;
    }
    let k = 1 + ((t - ttft) * r_c).floor() as u32;
    k.min(n)
}

/// Latency samples drawn at dispatch time, before resource grants resolve.
///
/// Drawing these up front (in the legacy order: decision, server TTFT,
/// device prefill) keeps the per-request random stream identical no matter
/// when the fleet loop resolves the request, so the unlimited-pool fleet
/// run is byte-identical to the historical per-request replay.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PreDrawn {
    pub decision: Decision,
    /// Intrinsic server prefill latency sample (None when the decision
    /// never dispatches to the server).
    pub server_sample: Option<f64>,
    /// Device prefill duration sample (always drawn, as the legacy path
    /// did, so streams stay aligned).
    pub dev_prefill_dur: f64,
}

pub(crate) fn pre_draw(
    req: &Request,
    policy: &Policy,
    server: &ServerEndpoint,
    device: &DeviceEndpoint,
    rng: &mut Rng,
) -> PreDrawn {
    let l = req.prompt_len;
    let decision = policy.decide(l, rng);
    let server_sample = if decision.uses_server() {
        Some(server.sample_ttft(l, rng))
    } else {
        None
    };
    let dev_prefill_dur = device.sample_ttft(l, rng);
    PreDrawn {
        decision,
        server_sample,
        dev_prefill_dur,
    }
}

/// Batch-occupancy context for the resolving stream (continuous
/// batching within a shard): multipliers the fleet loop derived from
/// the shard's [`crate::sim::batching::BatchLatencyCurve`] at the batch
/// size each server-side decode joined. The default (both 1.0 — slot
/// semantics) leaves every sampled gap bit-identical, preserving the
/// legacy replay byte-for-byte (IEEE-754 multiplication by 1.0 is
/// exact).
#[derive(Clone, Copy, Debug)]
pub(crate) struct BatchCtx {
    /// Multiplier on the winner-side server decode gaps (the batch the
    /// stream joined at admission).
    pub decode_slowdown: f64,
    /// Multiplier on the §4.3 migrated tail's server decode gaps (the
    /// target shard's batch at booking time).
    pub migration_decode_slowdown: f64,
}

impl Default for BatchCtx {
    fn default() -> Self {
        BatchCtx {
            decode_slowdown: 1.0,
            migration_decode_slowdown: 1.0,
        }
    }
}

/// Absolute times at which the contended resources were granted.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ResourceTimes {
    /// When the server admitted the request (prefill start). `None` when
    /// the request never dispatched to the server, or was cancelled while
    /// still queued (the device produced a token first).
    pub server_admit: Option<f64>,
    /// When the single-flight device became available to the request;
    /// `f64::INFINITY` when the device was never granted (unused, or the
    /// server produced a token while the request was still queued).
    pub device_grant: f64,
}

/// What a §4.3 migration did, surfaced so the fleet loop can book the
/// migrated stream onto its target shard's slot pool.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MigrationInfo {
    /// Endpoint generation moved to.
    pub target: EndpointKind,
    /// Absolute time the migrated stream's last token is generated —
    /// when the target shard's occupancy releases.
    pub end_abs: f64,
    /// Sampled migration overhead (target re-prefill + RTT), the work
    /// estimate the target shard carries while the stream runs.
    pub t_m: f64,
    /// Tokens the target re-prefilled (prompt + generated prefix).
    pub reprefill_len: u32,
}

/// A resolved request trajectory plus the resource-release times the
/// fleet loop needs to schedule.
#[derive(Clone, Debug)]
pub(crate) struct Resolved {
    pub record: RequestRecord,
    /// Absolute time the device frees (None when never held).
    pub device_busy_until: Option<f64>,
    /// Absolute time the server admission slot frees (None when never
    /// admitted).
    pub server_release: Option<f64>,
    /// Set when generation migrated endpoints mid-decode (§4.3).
    pub migration: Option<MigrationInfo>,
    /// Raw *generation* times of every token, relative to arrival
    /// (`gen_rel[0]` = TTFT) — the pre-smoothing timeline the record's
    /// delivered `tbts` were derived from. The fleet's iteration-level
    /// repricing path re-stamps this vector mid-run and re-smooths it
    /// at stream completion (deferred finalization); join-time runs
    /// drop it untouched.
    pub gen_rel: Vec<f64>,
}

/// Borrowed view of the server endpoint a §4.3 server-bound re-prefill
/// estimates and samples against: the target shard's profile by
/// reference, plus a pre-combined RTT offset (shard RTT + predicted
/// admission-queue delay). Replaces the per-resolve `ServerEndpoint`
/// clone the migration path used to make on every migrated stream — the
/// float arithmetic mirrors [`ServerEndpoint`]'s `SimEndpoint` impl
/// operation-for-operation, so records stay byte-identical.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MigrationServer<'a> {
    profile: &'a ServerProfile,
    extra_rtt: f64,
}

impl<'a> MigrationServer<'a> {
    /// View of an endpoint as-is (fallback target: the stream's own
    /// shard, or the scenario's base server).
    pub fn of(ep: &'a ServerEndpoint) -> MigrationServer<'a> {
        MigrationServer {
            profile: &ep.profile,
            extra_rtt: ep.extra_rtt,
        }
    }

    /// View of an endpoint with a caller-combined RTT offset (the target
    /// shard's `extra_rtt` plus its predicted re-prefill queue delay —
    /// the caller does the addition so the operand order matches the
    /// historical `ep.extra_rtt += delay` mutation exactly).
    pub fn with_extra_rtt(ep: &'a ServerEndpoint, extra_rtt: f64) -> MigrationServer<'a> {
        MigrationServer {
            profile: &ep.profile,
            extra_rtt,
        }
    }

    /// Mirrors `ServerEndpoint::expected_ttft`.
    fn expected_ttft(&self, _prompt_len: u32) -> f64 {
        self.extra_rtt + self.profile.mean_ttft()
    }

    /// Mirrors `ServerEndpoint::sample_ttft`.
    fn sample_ttft(&self, _prompt_len: u32, rng: &mut Rng) -> f64 {
        self.extra_rtt + self.profile.sample_ttft(rng)
    }

    /// Mirrors `ServerEndpoint::sample_gaps`.
    fn sample_gaps(&self, _ctx_len: u32, n: u32, rng: &mut Rng) -> Vec<f64> {
        self.profile.sample_gaps(n, rng)
    }
}

/// Simulate one request given its resource-grant times. Times inside are
/// relative to arrival; `ResourceTimes` converts through absolute time.
///
/// `migration_server` is the borrowed server view a §4.3 server-bound
/// re-prefill estimates and samples against — the *target shard* under
/// shard-targeted migration (its RTT plus any predicted queue delay
/// pre-combined into the view's `extra_rtt`). `None` falls back to
/// `server`, the historical single-target behavior, byte-for-byte.
///
/// `batch` scales server-side decode gaps by the fleet's batch-latency
/// curve (continuous batching); `BatchCtx::default()` (both factors
/// 1.0) is the slot-legacy identity.
#[allow(clippy::too_many_arguments)]
pub(crate) fn resolve_request(
    req: &Request,
    pre: &PreDrawn,
    policy: &Policy,
    server: &ServerEndpoint,
    device: &DeviceEndpoint,
    migration_server: Option<MigrationServer<'_>>,
    planner: &MigrationPlanner,
    cfg: &SimConfig,
    times: ResourceTimes,
    batch: BatchCtx,
    rng: &mut Rng,
) -> Resolved {
    let migration_server = migration_server.unwrap_or(MigrationServer::of(server));
    let l = req.prompt_len;
    let n = req.output_len.min(cfg.gen_limit).max(1);
    let r_c = cfg.migration.consumption_rate;
    let decision = pre.decision;

    let mut cost = CostMeter::default();

    // --- prefill race -------------------------------------------------
    let use_server = decision.uses_server();
    // Perceived server TTFT = admission-queue delay + intrinsic prefill.
    let server_first = match (times.server_admit, pre.server_sample) {
        (Some(admit), Some(sample)) => Some((admit - req.arrival).max(0.0) + sample),
        _ => None,
    };

    let device_wait = match decision {
        Decision::DeviceOnly => 0.0,
        Decision::ServerOnly => f64::INFINITY,
        Decision::Both { device_wait } => device_wait,
    };
    // Device is single-flight: wait for the grant from the device queue.
    let queue_wait = (times.device_grant - req.arrival).max(0.0);
    let dev_start = device_wait.max(queue_wait);
    let mut use_device = decision.uses_device() && dev_start.is_finite();
    // The wait-time strategy (§4.2): skip device start if the server
    // already produced a token.
    if use_device {
        if let Some(sf) = server_first {
            if sf <= dev_start {
                use_device = false;
            }
        }
    }
    let dev_prefill_dur = pre.dev_prefill_dur;
    let device_first = dev_start + dev_prefill_dur;

    assert!(
        use_server || use_device,
        "request {} dispatched nowhere",
        req.id
    );

    let (winner, ttft) = match (use_server.then_some(server_first).flatten(), use_device) {
        (Some(sf), true) => {
            if sf <= device_first {
                (EndpointKind::Server, sf)
            } else {
                (EndpointKind::Device, device_first)
            }
        }
        (Some(sf), false) => (EndpointKind::Server, sf),
        (None, true) => (EndpointKind::Device, device_first),
        (None, false) => unreachable!(),
    };

    // Prefill costs. The server bills the full prompt once dispatched
    // (even when cancelled in the admission queue — the request left the
    // client and the provider meters it); the device burns energy for
    // however much prefill it ran.
    if use_server {
        cost.server_prefill_tokens += l as u64;
    }
    let mut device_busy_until_rel: f64 = f64::NEG_INFINITY;
    if use_device {
        if winner == EndpointKind::Device {
            cost.device_prefill_tokens += l as u64;
        } else {
            // Cancelled mid-prefill at `ttft`.
            let elapsed = (ttft - dev_start).max(0.0);
            let done = ((elapsed / dev_prefill_dur) * l as f64).ceil() as u64;
            cost.device_prefill_tokens += done.min(l as u64);
            device_busy_until_rel = ttft;
        }
    }

    // --- decode -------------------------------------------------------
    // Token i (1-based) generated at gen[i-1]; token 1 at ttft.
    // Server decode pays the batch slowdown (×1.0 under slot legacy —
    // bit-exact, so the replay parity is preserved); device decode is
    // single-flight and never batched.
    let mut gen = Vec::with_capacity(n as usize);
    gen.push(ttft);
    {
        let (gaps, scale) = match winner {
            EndpointKind::Server => (server.sample_gaps(l, n - 1, rng), batch.decode_slowdown),
            EndpointKind::Device => (device.sample_gaps(l, n - 1, rng), 1.0),
        };
        for g in gaps {
            gen.push(gen.last().unwrap() + g * scale);
        }
    }

    // --- migration (§4.3) ----------------------------------------------
    let mut migrated = false;
    let mut migrate_at_idx = 0u32; // tokens produced by the source
    let mut migration: Option<MigrationInfo> = None;
    if policy.migration {
        if let Some(constraint) = policy.constraint() {
            if let Some(target) = planner.direction(constraint, winner) {
                // In server-constrained scenarios migrating to the device
                // must respect single-flight occupancy: only migrate if
                // the device is free (it is, for this request, unless a
                // previous request still runs — approximated by
                // queue_wait == 0).
                let target_available = match target {
                    EndpointKind::Device => queue_wait <= 0.0,
                    EndpointKind::Server => true,
                };
                if target_available {
                    // Walk the stream until the buffer masks t_m (Eq. 5)
                    // and Eq. 4 still favors migrating.
                    for i in 1..n {
                        let reprefill = l + i;
                        let t_exp = match target {
                            EndpointKind::Server => migration_server.expected_ttft(reprefill),
                            EndpointKind::Device => device.expected_ttft(reprefill),
                        };
                        if let Some(plan) =
                            planner.plan(constraint, winner, n - i, reprefill, t_exp)
                        {
                            let t_now = gen[i as usize - 1];
                            let buffered =
                                i.saturating_sub(consumed_at(t_now, ttft, r_c, n));
                            if buffered >= plan.buffer_tokens {
                                // Trigger: target re-prefills prompt+prefix.
                                migrated = true;
                                migrate_at_idx = i;
                                let t_m_actual = planner.config.rtt
                                    + match target {
                                        EndpointKind::Server => {
                                            migration_server.sample_ttft(reprefill, rng)
                                        }
                                        EndpointKind::Device => {
                                            device.sample_ttft(reprefill, rng)
                                        }
                                    };
                                let ready = t_now + t_m_actual;
                                // Rebuild the tail from the target. A
                                // server-bound tail decodes inside the
                                // target shard's batch (×1.0 legacy).
                                gen.truncate(i as usize);
                                gen.push(ready);
                                let (gaps, scale) = match target {
                                    EndpointKind::Server => (
                                        migration_server.sample_gaps(reprefill, n - i - 1, rng),
                                        batch.migration_decode_slowdown,
                                    ),
                                    EndpointKind::Device => {
                                        (device.sample_gaps(reprefill, n - i - 1, rng), 1.0)
                                    }
                                };
                                for g in gaps {
                                    gen.push(gen.last().unwrap() + g * scale);
                                }
                                // Costs: source decoded i tokens, target
                                // re-prefilled and decodes the rest.
                                match winner {
                                    EndpointKind::Server => {
                                        cost.server_decode_tokens += i as u64
                                    }
                                    EndpointKind::Device => {
                                        cost.device_decode_tokens += i as u64
                                    }
                                }
                                match target {
                                    EndpointKind::Server => {
                                        cost.server_prefill_tokens += reprefill as u64;
                                        cost.server_decode_tokens += (n - i) as u64;
                                    }
                                    EndpointKind::Device => {
                                        cost.device_prefill_tokens += reprefill as u64;
                                        cost.device_decode_tokens += (n - i) as u64;
                                    }
                                }
                                migration = Some(MigrationInfo {
                                    target,
                                    end_abs: req.arrival + *gen.last().unwrap(),
                                    t_m: t_m_actual,
                                    reprefill_len: reprefill,
                                });
                                break;
                            }
                        }
                    }
                }
            }
        }
    }
    if !migrated {
        match winner {
            EndpointKind::Server => cost.server_decode_tokens += n as u64,
            EndpointKind::Device => cost.device_decode_tokens += n as u64,
        }
    }

    // --- device occupancy ----------------------------------------------
    let device_active = use_device
        && (winner == EndpointKind::Device
            || device_busy_until_rel > f64::NEG_INFINITY);
    let mut device_busy_until: Option<f64> = None;
    if device_active {
        let until = if winner == EndpointKind::Device {
            if migrated {
                gen[migrate_at_idx as usize - 1]
            } else {
                *gen.last().unwrap()
            }
        } else {
            device_busy_until_rel
        };
        device_busy_until = Some(req.arrival + until);
    }
    if migrated && winner == EndpointKind::Server {
        // Device became the decode target.
        let t = req.arrival + *gen.last().unwrap();
        device_busy_until = Some(device_busy_until.map_or(t, |u| u.max(t)));
    }

    // --- server slot release --------------------------------------------
    // The admission slot is held from admit until the server-side stream
    // ends: last generated token (or the handoff point when generation
    // migrated off the server), or the cancellation moment when the
    // server lost the prefill race. Migration *onto* the server joins the
    // running batch and is not modeled as a second admission.
    let server_release = times.server_admit.map(|admit| {
        let rel = if winner == EndpointKind::Server {
            if migrated {
                gen[migrate_at_idx as usize - 1]
            } else {
                *gen.last().unwrap()
            }
        } else {
            ttft
        };
        (req.arrival + rel).max(admit)
    });

    // --- delivery smoothing & metrics -----------------------------------
    let d = delivery::smooth(&gen, r_c);

    let record = RequestRecord {
        id: req.id,
        prompt_len: l,
        output_len: n,
        ttft,
        server_queue_delay: times
            .server_admit
            .map_or(0.0, |admit| (admit - req.arrival).max(0.0)),
        device_queue_delay: if queue_wait.is_finite() { queue_wait } else { 0.0 },
        tbts: d.tbts,
        delay_num: d.delay_num,
        migrated,
        winner,
        cost,
        used_server: use_server,
        used_device: use_device,
    };
    Resolved {
        record,
        device_busy_until,
        server_release,
        migration,
        gen_rel: gen,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::PolicyKind;
    use crate::trace::generator::WorkloadSpec;

    fn scenario(constraint: Constraint, seed: u64) -> Scenario {
        Scenario::new(
            ServerProfile::gpt4o_mini(),
            DeviceProfile::pixel7pro_bloom560m(),
            constraint,
            SimConfig {
                seed,
                ..Default::default()
            },
        )
    }

    fn planned(kind: PolicyKind, b: f64, migration: bool, sc: &Scenario, trace: &Trace) -> Policy {
        let ecdf = sc.profile_server_ttft(2000, 1);
        let lens = trace.prompt_lens();
        match kind {
            PolicyKind::DiscoS | PolicyKind::DiscoD => {
                Policy::plan(kind, b, migration, &ecdf, &lens)
            }
            _ => Policy::simple(kind, b, migration),
        }
    }

    #[test]
    fn server_only_matches_server_distribution() {
        let sc = scenario(Constraint::Server, 7);
        let trace = WorkloadSpec::alpaca(500).generate(3);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let report = sc.run_report(&trace, &policy);
        assert_eq!(report.n, 500);
        // Mean near the GPT profile's mean TTFT.
        let expected = sc.server.profile.mean_ttft();
        assert!(
            (report.ttft.mean - expected).abs() / expected < 0.25,
            "mean {} vs profile {}",
            report.ttft.mean,
            expected
        );
        // No device usage at all.
        assert_eq!(report.cost.device_prefill_tokens, 0);
        assert_eq!(report.cost.device_decode_tokens, 0);
    }

    #[test]
    fn device_only_ttft_scales_with_length() {
        let sc = scenario(Constraint::Server, 8);
        // Wide fixed gaps isolate prefill scaling from queueing (the
        // paper's §3 methodology: identical prompts at 60 s intervals).
        let trace = WorkloadSpec {
            arrival: crate::trace::generator::Arrival::Fixed { gap: 120.0 },
            ..WorkloadSpec::alpaca(300)
        }
        .generate(4);
        let policy = Policy::simple(PolicyKind::DeviceOnly, 1.0, false);
        let records = sc.run(&trace, &policy);
        let xs: Vec<f64> = records.iter().map(|r| r.prompt_len as f64).collect();
        let ys: Vec<f64> = records.iter().map(|r| r.ttft).collect();
        let r = crate::stats::corr::pearson(&xs, &ys);
        assert!(r > 0.7, "device TTFT should correlate with length, r={r}");
        for rec in &records {
            assert_eq!(rec.winner, EndpointKind::Device);
            assert!(!rec.used_server);
        }
    }

    #[test]
    fn both_dispatch_beats_either_alone() {
        // Racing both endpoints: TTFT = min of the two ⇒ mean TTFT must
        // be ≤ each single-endpoint policy (same seeds).
        let sc = scenario(Constraint::Server, 9);
        let trace = WorkloadSpec::alpaca(600).generate(5);
        let both = Policy::simple(PolicyKind::StochS, 1.0, false); // b=1 ⇒ always Both
        let server = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let device = Policy::simple(PolicyKind::DeviceOnly, 1.0, false);
        let rb = sc.run_report(&trace, &both);
        let rs = sc.run_report(&trace, &server);
        let rd = sc.run_report(&trace, &device);
        assert!(rb.ttft.mean <= rs.ttft.mean * 1.02);
        assert!(rb.ttft.mean <= rd.ttft.mean * 1.02);
        assert!(rb.ttft.p99 <= rs.ttft.p99 * 1.05);
    }

    #[test]
    fn disco_s_respects_server_budget_at_runtime() {
        let sc = scenario(Constraint::Server, 10);
        let trace = WorkloadSpec::alpaca(1500).generate(6);
        for b in [0.1, 0.4, 0.8] {
            let policy = planned(PolicyKind::DiscoS, b, false, &sc, &trace);
            let report = sc.run_report(&trace, &policy);
            let frac = report.constrained_prefill_fraction.unwrap();
            assert!(
                frac <= b + 0.06,
                "b={b}: server prefill fraction {frac:.3}"
            );
        }
    }

    #[test]
    fn disco_d_respects_device_budget_at_runtime() {
        let sc = scenario(Constraint::Device, 11);
        let trace = WorkloadSpec::alpaca(1500).generate(7);
        for b in [0.1, 0.4, 0.8] {
            let policy = planned(PolicyKind::DiscoD, b, false, &sc, &trace);
            let report = sc.run_report(&trace, &policy);
            let frac = report.constrained_prefill_fraction.unwrap();
            assert!(
                frac <= b + 0.08,
                "b={b}: device prefill fraction {frac:.3}"
            );
        }
    }

    #[test]
    fn migration_reduces_cost_device_constrained() {
        // Fig. 7's claim: with migration, end-to-end cost drops.
        let sc = scenario(Constraint::Device, 12);
        let trace = WorkloadSpec::alpaca(800).generate(8);
        let with = planned(PolicyKind::DiscoD, 0.6, true, &sc, &trace);
        let without = planned(PolicyKind::DiscoD, 0.6, false, &sc, &trace);
        let rw = sc.run_report(&trace, &with);
        let ro = sc.run_report(&trace, &without);
        assert!(rw.migrated_requests > 0, "some requests must migrate");
        let cw = rw.total_cost(&sc.costs);
        let co = ro.total_cost(&sc.costs);
        assert!(
            cw < co,
            "migration should cut cost: with={cw:.4} without={co:.4}"
        );
    }

    #[test]
    fn migration_preserves_tbt() {
        // Table 3's claim: migration does not break delivery smoothness.
        let sc = scenario(Constraint::Device, 13);
        let trace = WorkloadSpec::alpaca(600).generate(9);
        let policy = planned(PolicyKind::DiscoD, 0.6, true, &sc, &trace);
        let report = sc.run_report(&trace, &policy);
        let r_c = sc.cfg.migration.consumption_rate;
        // P99 TBT stays near the consumption interval (paper: 0.209–0.217
        // at r_c = 5).
        assert!(
            report.tbt.p99 < 1.5 / r_c,
            "TBT p99 {} vs 1/r_c {}",
            report.tbt.p99,
            1.0 / r_c
        );
        // Delayed tokens are few relative to generation lengths.
        assert!(
            report.delay_num_mean < 20.0,
            "delay_num mean {}",
            report.delay_num_mean
        );
    }

    #[test]
    fn runs_are_deterministic() {
        let sc = scenario(Constraint::Server, 14);
        let trace = WorkloadSpec::alpaca(200).generate(10);
        let policy = planned(PolicyKind::DiscoS, 0.5, true, &sc, &trace);
        let a = sc.run(&trace, &policy);
        let b = sc.run(&trace, &policy);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ttft, y.ttft);
            assert_eq!(x.migrated, y.migrated);
            assert_eq!(x.cost, y.cost);
        }
    }

    #[test]
    fn device_occupancy_serializes_requests() {
        // Two requests arriving back-to-back on device-only must queue.
        let sc = scenario(Constraint::Server, 15);
        let trace = Trace::new(
            "b2b",
            vec![
                Request {
                    id: 0,
                    arrival: 0.0,
                    prompt_len: 400,
                    output_len: 64,
                },
                Request {
                    id: 1,
                    arrival: 0.1,
                    prompt_len: 400,
                    output_len: 64,
                },
            ],
        );
        let policy = Policy::simple(PolicyKind::DeviceOnly, 1.0, false);
        let mut sc_q = sc.clone();
        sc_q.cfg.device_queueing = true;
        let records = sc_q.run(&trace, &policy);
        // Request 1's TTFT includes waiting for request 0's completion.
        assert!(
            records[1].ttft > records[0].ttft * 1.5,
            "queued TTFT {} vs {}",
            records[1].ttft,
            records[0].ttft
        );
        assert!(records[1].device_queue_delay > 0.0);
        // With queueing off (paper methodology) the two are independent.
        let records = sc.run(&trace, &policy);
        assert!(records[1].ttft < records[0].ttft * 1.5);
        assert_eq!(records[1].device_queue_delay, 0.0);
    }

    /// Regression for the dying-shard migration fallback: the §4.3
    /// re-prefill endpoint's RTT must flow into the migrated stream's
    /// timing (the old fallback silently dropped the victim shard's
    /// offset, undercounting migration latency). With Eq. 5 buffering
    /// ablated (`buffer_scale = 0`, one-token floor) and a warm-up far
    /// above the pacing slack, a +0.5 s RTT on the migration target
    /// shifts the sampled `t_m`, the last generated token, and the
    /// delivered completion time by exactly 0.5 s — same handoff index,
    /// same cost split, same draws.
    #[test]
    fn migration_endpoint_rtt_shifts_migrated_stream_by_exactly_delta() {
        let cfg = SimConfig {
            migration: MigrationConfig {
                enabled: true,
                consumption_rate: 5.0,
                rtt: 0.05,
                buffer_scale: 0.0,
            },
            ..Default::default()
        };
        // Device decode far above server decode: Eq. 4 always favors
        // migrating device-won streams onto the server.
        let costs = CostParams {
            server_prefill: 1e-7,
            server_decode: 6e-7,
            device_prefill: 1.2e-7,
            device_decode: 5e-6,
        };
        let planner = MigrationPlanner::new(cfg.migration, costs);
        let policy = Policy::simple(crate::coordinator::policy::PolicyKind::StochD, 1.0, true);
        let src = ServerEndpoint::new(ServerProfile::gpt4o_mini());
        let device = DeviceEndpoint::new(DeviceProfile::pixel7pro_bloom560m());
        let req = Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 64,
            output_len: 32,
        };
        let pre = PreDrawn {
            decision: Decision::Both { device_wait: 0.0 },
            server_sample: Some(9.0), // server loses the race decisively
            dev_prefill_dur: 0.05,
        };
        let times = ResourceTimes {
            server_admit: None, // cancelled in queue: device won first
            device_grant: 0.0,
        };
        let resolve_with = |rtt: f64| {
            let target = ServerEndpoint::with_rtt(ServerProfile::gpt4o_mini(), rtt);
            let mut rng = Rng::new(42);
            resolve_request(
                &req,
                &pre,
                &policy,
                &src,
                &device,
                Some(MigrationServer::of(&target)),
                &planner,
                &cfg,
                times,
                BatchCtx::default(),
                &mut rng,
            )
        };
        let a = resolve_with(5.0);
        let b = resolve_with(5.5);
        assert!(a.record.migrated && b.record.migrated, "both must migrate");
        let (ma, mb) = (a.migration.unwrap(), b.migration.unwrap());
        assert_eq!(ma.target, EndpointKind::Server);
        assert_eq!(ma.reprefill_len, mb.reprefill_len, "handoff index must match");
        // Identical token split ⇒ identical cost meters.
        assert_eq!(a.record.cost, b.record.cost);
        assert!(
            (mb.t_m - ma.t_m - 0.5).abs() < 1e-9,
            "t_m must shift by the RTT delta: {} vs {}",
            ma.t_m,
            mb.t_m
        );
        assert!((mb.end_abs - ma.end_abs - 0.5).abs() < 1e-9);
        let done = |r: &Resolved| r.record.ttft + r.record.tbts.iter().sum::<f64>();
        assert!(
            (done(&b) - done(&a) - 0.5).abs() < 1e-9,
            "delivered completion must inherit the RTT delta: {} vs {}",
            done(&a),
            done(&b)
        );
        assert!(b.record.delay_num >= a.record.delay_num);
    }

    /// Batch-occupancy decode pricing: the same request resolved with a
    /// decode slowdown keeps its TTFT and draws (prefill and the race
    /// are batch-independent) but stretches every raw generation gap by
    /// exactly the factor — and the identity factor 1.0 is bit-exact,
    /// the property the slot-legacy byte-parity rests on.
    #[test]
    fn batch_ctx_scales_server_decode_gaps_exactly() {
        let cfg = SimConfig::default();
        let sc = scenario(Constraint::Server, 18);
        let planner = MigrationPlanner::new(cfg.migration, sc.costs);
        let policy = Policy::simple(PolicyKind::ServerOnly, 1.0, false);
        let req = Request {
            id: 0,
            arrival: 0.0,
            prompt_len: 64,
            output_len: 32,
        };
        let pre = PreDrawn {
            decision: Decision::ServerOnly,
            server_sample: Some(0.4),
            dev_prefill_dur: 0.1,
        };
        let times = ResourceTimes {
            server_admit: Some(0.0),
            device_grant: f64::INFINITY,
        };
        let resolve_with = |slow: f64| {
            let mut rng = Rng::new(77);
            resolve_request(
                &req,
                &pre,
                &policy,
                &sc.server,
                &sc.device,
                None,
                &planner,
                &cfg,
                times,
                BatchCtx {
                    decode_slowdown: slow,
                    migration_decode_slowdown: 1.0,
                },
                &mut rng,
            )
        };
        let base = resolve_with(1.0);
        let slowed = resolve_with(3.0);
        assert_eq!(base.record.ttft.to_bits(), slowed.record.ttft.to_bits());
        // The slot-hold (admit → last generated token) stretches by the
        // factor: release − admit = ttft + Σ raw gaps × slowdown.
        let hold = |r: &Resolved| r.server_release.unwrap() - r.record.ttft;
        assert!(
            (hold(&slowed) - 3.0 * hold(&base)).abs() < 1e-9,
            "decode span must scale exactly: {} vs 3×{}",
            hold(&slowed),
            hold(&base)
        );
        // Identity is bit-exact (the parity guarantee).
        let again = resolve_with(1.0);
        assert_eq!(base.server_release.unwrap().to_bits(), again.server_release.unwrap().to_bits());
        assert_eq!(base.record, again.record);
    }

    #[test]
    fn prop_ttft_positive_and_tokens_conserved() {
        let sc = scenario(Constraint::Device, 16);
        crate::proptest::check(
            "sim-conservation",
            32,
            |r| {
                let n = 20 + r.below(80) as usize;
                let seed = r.next_u64();
                let b = r.f64();
                (n, seed, b)
            },
            |&(n, seed, b)| {
                let trace = WorkloadSpec::alpaca(n).generate(seed);
                let ecdf = sc.profile_server_ttft(500, seed);
                let policy = Policy::plan(
                    PolicyKind::DiscoD,
                    b,
                    true,
                    &ecdf,
                    &trace.prompt_lens(),
                );
                let records = sc.run(&trace, &policy);
                for rec in &records {
                    crate::prop_assert!(rec.ttft > 0.0, "ttft {} <= 0", rec.ttft);
                    crate::prop_assert!(
                        rec.tbts.len() as u32 == rec.output_len - 1,
                        "tbt count {} vs output {}",
                        rec.tbts.len(),
                        rec.output_len
                    );
                    // Decode tokens across endpoints must equal output_len.
                    let decoded =
                        rec.cost.server_decode_tokens + rec.cost.device_decode_tokens;
                    crate::prop_assert!(
                        decoded == rec.output_len as u64,
                        "decoded {decoded} vs output {}",
                        rec.output_len
                    );
                }
                Ok(())
            },
        );
    }
}
