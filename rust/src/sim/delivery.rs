//! Token delivery smoothing (§4.3, Fig. 4).
//!
//! Generation runs faster than human consumption (r_g > r_c, §2.2), so
//! perceived TBT is the *delivery* gap, not the raw generation gap: the
//! client paces tokens at the consumption rate while a buffer absorbs
//! generation jitter. A token is **delayed** (Table 3's `delay_num`) when
//! it is not yet generated at the moment the consumption schedule wants
//! it.

/// Result of smoothing one request's token stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Delivery {
    /// When each token is shown to the user (absolute, seconds).
    pub read_times: Vec<f64>,
    /// Perceived inter-token gaps (len = tokens − 1).
    pub tbts: Vec<f64>,
    /// Number of tokens that missed the consumption schedule.
    pub delay_num: u32,
}

/// Smooth generation times into a delivery schedule at consumption rate
/// `r_c` tokens/s. `gen_times` must be nondecreasing; the first entry is
/// the TTFT.
pub fn smooth(gen_times: &[f64], r_c: f64) -> Delivery {
    assert!(r_c > 0.0);
    if gen_times.is_empty() {
        return Delivery {
            read_times: vec![],
            tbts: vec![],
            delay_num: 0,
        };
    }
    let step = 1.0 / r_c;
    let mut read_times = Vec::with_capacity(gen_times.len());
    let mut tbts = Vec::with_capacity(gen_times.len().saturating_sub(1));
    let mut delay_num = 0u32;
    read_times.push(gen_times[0]);
    for i in 1..gen_times.len() {
        let want = read_times[i - 1] + step;
        let actual = if gen_times[i] > want + 1e-9 {
            // Token wasn't ready when the user wanted it.
            delay_num += 1;
            gen_times[i]
        } else {
            want
        };
        tbts.push(actual - read_times[i - 1]);
        read_times.push(actual);
    }
    Delivery {
        read_times,
        tbts,
        delay_num,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_generation_paces_at_consumption_rate() {
        // Tokens generated every 50 ms, consumed at 5/s (200 ms).
        let gen: Vec<f64> = (0..20).map(|i| 1.0 + 0.05 * i as f64).collect();
        let d = smooth(&gen, 5.0);
        assert_eq!(d.delay_num, 0);
        for tbt in &d.tbts {
            assert!((tbt - 0.2).abs() < 1e-9);
        }
        assert_eq!(d.read_times[0], 1.0);
    }

    #[test]
    fn slow_tokens_are_counted_delayed() {
        // Second token arrives 1 s after the first: delayed.
        let d = smooth(&[0.0, 1.0, 1.05], 5.0);
        assert_eq!(d.delay_num, 1);
        assert!((d.tbts[0] - 1.0).abs() < 1e-9);
        // Third token was already buffered: paced at 0.2.
        assert!((d.tbts[1] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn gap_burst_absorbed_by_buffer() {
        // Packetized arrival: 4 tokens at once, then a 0.5 s stall, 4 more.
        let gen = vec![0.0, 0.0, 0.0, 0.0, 0.5, 0.5, 0.5, 0.5];
        let d = smooth(&gen, 5.0);
        // Schedule wants tokens at 0, .2, .4, .6, .8 ... the stall until
        // 0.5 is fully hidden (token 5 wanted at 0.8 > 0.5).
        assert_eq!(d.delay_num, 0);
        for tbt in &d.tbts {
            assert!((tbt - 0.2).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(smooth(&[], 5.0).read_times.len(), 0);
        let d = smooth(&[2.5], 5.0);
        assert_eq!(d.read_times, vec![2.5]);
        assert!(d.tbts.is_empty());
        assert_eq!(d.delay_num, 0);
    }

    #[test]
    fn prop_read_times_monotone_and_cover_gen() {
        crate::proptest::check(
            "delivery-monotone",
            128,
            |r| {
                let n = 1 + r.below(200) as usize;
                let mut t = r.f64() * 2.0;
                let mut gen = Vec::with_capacity(n);
                for _ in 0..n {
                    gen.push(t);
                    t += r.f64() * 0.5;
                }
                let rc = 1.0 + r.f64() * 9.0;
                (gen, rc)
            },
            |(gen, rc)| {
                let d = smooth(gen, *rc);
                crate::prop_assert!(d.read_times.len() == gen.len(), "len mismatch");
                for i in 1..d.read_times.len() {
                    crate::prop_assert!(
                        d.read_times[i] >= d.read_times[i - 1],
                        "read times must be monotone"
                    );
                    // Never shown before it exists, never slower than r_c
                    // once buffered.
                    crate::prop_assert!(
                        d.read_times[i] + 1e-9 >= gen[i],
                        "token shown before generated"
                    );
                    crate::prop_assert!(
                        d.read_times[i] + 1e-9 >= d.read_times[i - 1] + 1.0 / rc,
                        "faster than consumption rate"
                    );
                }
                Ok(())
            },
        );
    }
}
