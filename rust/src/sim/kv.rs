//! Paged KV-cache memory model for a shard (the vLLM block-pool view).
//!
//! Continuous batching (PR 5) gates admission on an abstract
//! prompt-token budget; the real constraint in vLLM-class servers is KV
//! memory. This module models it directly: each shard owns a fixed pool
//! of equal-sized KV blocks ("pages"). A prefill allocates
//! `ceil(prompt / block_tokens)` pages up front; decode grows a
//! stream's usage one token at a time (a new page every `block_tokens`
//! emitted tokens). Admission blocks when free pages run out, oversized
//! prompts chunk Sarathi-style across scheduling ticks (the chunk
//! budget *accrues* while prompts wait instead of resetting), and under
//! memory pressure the fleet loop preempts the lowest-priority running
//! stream (evict-and-re-prefill; see `sim/fleet.rs`).
//!
//! Layered on top is a per-shard **prefix cache**: a sorted index of
//! block-aligned prompt lengths this shard has already prefilled. A hit
//! skips the cached fraction of prefill (shorter TTFT, fewer admission
//! tokens); hit-rate is surfaced through `LoadReport`. Session traces
//! (`trace/generator.rs`) share prompt-length distributions per user,
//! which is what makes the index hit in practice.
//!
//! The gate itself is event-free and draws no randomness: the fleet
//! loop calls [`KvGate::tick`]/[`KvGate::admits`]/[`KvGate::consume`]
//! from its existing tick machinery, so `SlotLegacy` and `Continuous`
//! runs are untouched by this module existing.

use crate::sim::batching::BatchLatencyCurve;
use std::collections::BTreeSet;

/// Tunables of the paged KV admission and memory model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvConfig {
    /// KV blocks (pages) in the shard's pool.
    pub pages: usize,
    /// Tokens of KV state one page holds.
    pub block_tokens: u32,
    /// Prefill tokens the shard may process per scheduling tick (the
    /// Sarathi chunk size). Unlike the continuous-batching budget, this
    /// budget accrues across non-idle ticks, so a prompt larger than
    /// one chunk admits after enough ticks instead of jumping the gate.
    pub chunk_tokens: u32,
    /// Seconds between scheduling ticks (chunk accrual + page growth +
    /// pressure checks).
    pub tick_interval: f64,
    /// Whether the per-shard prefix cache is consulted.
    pub prefix_caching: bool,
    /// Entry budget of the per-shard prefix index. The index used to
    /// grow unboundedly within a run; it is now LRU-capped at this many
    /// block-aligned lengths, with evictions surfaced through
    /// `LoadReport::prefix_evictions`. Not part of the CLI label/parse
    /// spelling (`PAGES:BLOCK:CHUNK:cache|nocache` keeps its arity).
    pub prefix_cache_entries: usize,
    /// Optional time-to-live for prefix-index entries, in simulated
    /// seconds since the entry was last touched (inserted or served).
    /// `None` (the default) keeps the pure-LRU behavior byte-for-byte;
    /// `Some(ttl)` additionally expires stale entries at lookup/insert
    /// time, counting expirations into the same eviction total as LRU.
    /// Like the entry budget, not part of the label/parse spelling.
    pub prefix_cache_ttl: Option<f64>,
    /// Per-token decode latency vs batch size (same shape as
    /// continuous batching — paged admission changes *who* is in the
    /// batch, not how a batch decodes).
    pub curve: BatchLatencyCurve,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            pages: 2048,
            block_tokens: 16,
            chunk_tokens: 256,
            tick_interval: 0.25,
            prefix_caching: true,
            prefix_cache_entries: 1024,
            prefix_cache_ttl: None,
            curve: BatchLatencyCurve::Knee {
                knee: 8,
                alpha: 0.05,
            },
        }
    }
}

impl KvConfig {
    /// Sustained prefill throughput of the chunked scheduler
    /// (tokens/second) — the rate re-prefill delays are priced at.
    pub fn tokens_per_sec(&self) -> f64 {
        self.chunk_tokens as f64 / self.tick_interval
    }

    /// Clamp degenerate values (zero pages/blocks/chunks, non-positive
    /// tick) so the event loop can never stall on an un-replenishable
    /// budget or divide by a zero block size.
    pub fn normalized(&self) -> KvConfig {
        KvConfig {
            pages: self.pages.max(1),
            block_tokens: self.block_tokens.max(1),
            chunk_tokens: self.chunk_tokens.max(1),
            tick_interval: if self.tick_interval > 0.0 {
                self.tick_interval
            } else {
                0.25
            },
            prefix_caching: self.prefix_caching,
            prefix_cache_entries: self.prefix_cache_entries.max(1),
            prefix_cache_ttl: self.prefix_cache_ttl.filter(|t| *t > 0.0),
            curve: self.curve,
        }
    }

    /// Short label used in tables, CSVs, and CLI flags:
    /// `PAGES:BLOCK:CHUNK:cache|nocache`.
    pub fn label(&self) -> String {
        format!(
            "{}:{}:{}:{}",
            self.pages,
            self.block_tokens,
            self.chunk_tokens,
            if self.prefix_caching { "cache" } else { "nocache" }
        )
    }

    /// Parse a CLI spelling: `PAGES[:BLOCK[:CHUNK[:cache|nocache]]]`
    /// (omitted fields take the defaults). Trailing fields are rejected
    /// — a typo'd arity must error, not silently run a different pool.
    pub fn parse(s: &str) -> Option<KvConfig> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        let mut cfg = KvConfig::default();
        cfg.pages = parts.next()?.trim().parse::<usize>().ok()?;
        if let Some(p) = parts.next() {
            cfg.block_tokens = p.parse::<u32>().ok()?;
        }
        if let Some(p) = parts.next() {
            cfg.chunk_tokens = p.parse::<u32>().ok()?;
        }
        if let Some(p) = parts.next() {
            cfg.prefix_caching = match p {
                "cache" => true,
                "nocache" => false,
                _ => return None,
            };
        }
        if parts.next().is_some() {
            return None;
        }
        Some(cfg)
    }
}

impl std::fmt::Display for KvConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Per-shard paged-KV admission gate: page ledger + accruing chunk
/// budget + prefix index. Owned by the shard's `Pool` in
/// `sim/fleet.rs`; all timing decisions stay in the fleet event loop.
#[derive(Debug)]
pub struct KvGate {
    cfg: KvConfig,
    /// Pages currently allocated (prefills + decode growth). May exceed
    /// `cfg.pages` transiently — decode growth allocates on demand and
    /// the fleet loop resolves the pressure by preemption at the next
    /// tick.
    pages_used: usize,
    peak_pages: usize,
    /// Prefill chunk tokens available right now. Accrues one
    /// `chunk_tokens` per non-idle tick (never resets), so an oversized
    /// prompt waiting at the queue head accumulates budget across ticks
    /// — observable Sarathi chunking without splitting the event.
    budget_left: u64,
    admitted_tokens: u64,
    capacity_tokens: u64,
    /// Block-aligned prompt lengths this shard has prefilled — the
    /// prefix index. A new prompt's cached prefix is the largest
    /// indexed length not exceeding its own block-aligned length.
    /// LRU-capped at `cfg.prefix_cache_entries`.
    index: BTreeSet<u32>,
    /// Last-touch stamp per indexed length (monotone `clock` values),
    /// driving LRU eviction when the entry budget is exceeded.
    recency: std::collections::HashMap<u32, u64>,
    /// Last-touch *simulated time* per indexed length, driving TTL
    /// expiry when `cfg.prefix_cache_ttl` is set. Unused (empty checks
    /// aside) under pure LRU.
    touched: std::collections::HashMap<u32, f64>,
    clock: u64,
    evictions: u64,
    hits: u64,
    lookups: u64,
}

impl KvGate {
    pub fn new(cfg: &KvConfig) -> KvGate {
        let cfg = cfg.normalized();
        KvGate {
            cfg,
            pages_used: 0,
            peak_pages: 0,
            budget_left: cfg.chunk_tokens as u64,
            admitted_tokens: 0,
            capacity_tokens: cfg.chunk_tokens as u64,
            index: BTreeSet::new(),
            recency: std::collections::HashMap::new(),
            touched: std::collections::HashMap::new(),
            clock: 0,
            evictions: 0,
            hits: 0,
            lookups: 0,
        }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    pub fn pages_total(&self) -> usize {
        self.cfg.pages
    }

    pub fn pages_used(&self) -> usize {
        self.pages_used
    }

    pub fn peak_pages(&self) -> usize {
        self.peak_pages
    }

    /// Pages a context of `tokens` tokens occupies, capped at the pool
    /// size so a prompt larger than the entire pool can still admit
    /// when the pool is empty (liveness: it simply owns every page).
    pub fn pages_for(&self, tokens: u32) -> usize {
        let b = self.cfg.block_tokens as u64;
        let need = ((tokens as u64 + b - 1) / b) as usize;
        need.min(self.cfg.pages)
    }

    /// Whether a prefill of `tokens` (uncached) tokens admits right
    /// now: enough free pages for its prefill allocation AND enough
    /// accrued chunk budget to process the prompt this tick.
    pub fn admits(&self, tokens: u32) -> bool {
        self.pages_used + self.pages_for(tokens) <= self.cfg.pages
            && tokens as u64 <= self.budget_left
    }

    /// Consume an admission: charge the chunk budget and allocate the
    /// prefill pages. Callers must have checked [`Self::admits`].
    pub fn consume(&mut self, tokens: u32) {
        self.admitted_tokens += tokens as u64;
        self.budget_left = self.budget_left.saturating_sub(tokens as u64);
        self.alloc(self.pages_for(tokens));
    }

    /// Allocate `pages` pages (decode growth / booked re-prefills).
    pub fn alloc(&mut self, pages: usize) {
        self.pages_used += pages;
        if self.pages_used > self.peak_pages {
            self.peak_pages = self.pages_used;
        }
    }

    /// Return `pages` pages to the pool.
    pub fn free(&mut self, pages: usize) {
        self.pages_used = self.pages_used.saturating_sub(pages);
    }

    /// Charge re-prefill work (a preempted or failed-over stream's
    /// recompute) against the chunk budget without counting it as an
    /// admission — it delays new prefills, which is the real effect.
    pub fn charge(&mut self, tokens: u64) {
        self.budget_left = self.budget_left.saturating_sub(tokens);
    }

    /// Whether decode growth has pushed the ledger past the pool — the
    /// fleet loop's preemption trigger.
    pub fn over_capacity(&self) -> bool {
        self.pages_used > self.cfg.pages
    }

    /// Accrue one tick's chunk budget. The caller skips idle ticks
    /// (nothing queued): accruing while nothing waits would let a later
    /// burst admit unboundedly in one tick.
    pub fn tick(&mut self) {
        self.budget_left += self.cfg.chunk_tokens as u64;
        self.capacity_tokens += self.cfg.chunk_tokens as u64;
    }

    /// (admitted prefill tokens, chunk-budget capacity offered) — the
    /// token-budget utilization numerator/denominator.
    pub fn token_totals(&self) -> (u64, u64) {
        (self.admitted_tokens, self.capacity_tokens)
    }

    /// Prefix-cache lookup for a prompt of `len` tokens: returns the
    /// cached token count (0 = miss). The cached prefix is the longest
    /// block-aligned previously-prefilled length not exceeding this
    /// prompt's block-aligned length, clamped to `len − 1` so at least
    /// one token always prefills (TTFT stays positive). `now` is the
    /// simulated time of the lookup, consulted only under TTL expiry.
    pub fn prefix_lookup(&mut self, len: u32, now: f64) -> u32 {
        if !self.cfg.prefix_caching || len == 0 {
            return 0;
        }
        self.expire(now);
        self.lookups += 1;
        let aligned = len - len % self.cfg.block_tokens;
        let entry = self.index.range(..=aligned).next_back().copied();
        if let Some(e) = entry {
            // A hit refreshes the serving entry's LRU position.
            self.touch(e, now);
        }
        let cached = entry.unwrap_or(0).min(len.saturating_sub(1));
        if cached > 0 {
            self.hits += 1;
        }
        cached
    }

    /// Record a prompt of `len` tokens as prefilled on this shard at
    /// simulated time `now`, evicting the least-recently-used entry
    /// when the insert pushes the index past `cfg.prefix_cache_entries`
    /// (and expiring stale entries first under TTL).
    pub fn prefix_insert(&mut self, len: u32, now: f64) {
        if !self.cfg.prefix_caching {
            return;
        }
        self.expire(now);
        let aligned = len - len % self.cfg.block_tokens;
        if aligned == 0 {
            return;
        }
        self.index.insert(aligned);
        self.touch(aligned, now);
        while self.index.len() > self.cfg.prefix_cache_entries {
            // Stamps are unique (one monotone clock), so the argmin —
            // and with it the whole eviction order — is deterministic.
            let lru = self
                .recency
                .iter()
                .min_by_key(|(_, &stamp)| stamp)
                .map(|(&len, _)| len)
                .expect("index and recency stay in lockstep");
            self.index.remove(&lru);
            self.recency.remove(&lru);
            self.touched.remove(&lru);
            self.evictions += 1;
        }
    }

    /// TTL expiry pass: drop every entry whose last touch is older than
    /// `cfg.prefix_cache_ttl` seconds. The index is ordered, so the
    /// expiry order — and the eviction count — is deterministic. A
    /// no-op (no allocation, no counter movement) when TTL is unset.
    fn expire(&mut self, now: f64) {
        let Some(ttl) = self.cfg.prefix_cache_ttl else {
            return;
        };
        let stale: Vec<u32> = self
            .index
            .iter()
            .copied()
            .filter(|len| {
                self.touched
                    .get(len)
                    .map(|&at| now - at > ttl)
                    .unwrap_or(false)
            })
            .collect();
        for len in stale {
            self.index.remove(&len);
            self.recency.remove(&len);
            self.touched.remove(&len);
            self.evictions += 1;
        }
    }

    fn touch(&mut self, aligned: u32, now: f64) {
        self.clock += 1;
        self.recency.insert(aligned, self.clock);
        if self.cfg.prefix_cache_ttl.is_some() {
            self.touched.insert(aligned, now);
        }
    }

    /// (prefix-cache hits, lookups) since the gate was created.
    pub fn prefix_stats(&self) -> (u64, u64) {
        (self.hits, self.lookups)
    }

    /// Prefix-index entries evicted by the LRU entry budget.
    pub fn prefix_evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane_and_normalization_clamps() {
        let cfg = KvConfig::default();
        assert_eq!(cfg.normalized(), cfg, "sane configs are untouched");
        assert!((cfg.tokens_per_sec() - 1024.0).abs() < 1e-9);
        let bad = KvConfig {
            pages: 0,
            block_tokens: 0,
            chunk_tokens: 0,
            tick_interval: 0.0,
            ..KvConfig::default()
        }
        .normalized();
        assert_eq!(bad.pages, 1);
        assert_eq!(bad.block_tokens, 1);
        assert_eq!(bad.chunk_tokens, 1);
        assert!(bad.tick_interval > 0.0);
    }

    #[test]
    fn config_parse_roundtrips_and_rejects_trailing_fields() {
        let cfg = KvConfig::default();
        assert_eq!(KvConfig::parse(&cfg.label()), Some(cfg));
        let nc = KvConfig {
            prefix_caching: false,
            ..KvConfig::default()
        };
        assert_eq!(KvConfig::parse(&nc.label()), Some(nc));
        // Omitted fields take the defaults.
        assert_eq!(
            KvConfig::parse("512"),
            Some(KvConfig {
                pages: 512,
                ..KvConfig::default()
            })
        );
        assert_eq!(
            KvConfig::parse("512:32:128"),
            Some(KvConfig {
                pages: 512,
                block_tokens: 32,
                chunk_tokens: 128,
                ..KvConfig::default()
            })
        );
        assert!(KvConfig::parse("").is_none());
        assert!(KvConfig::parse("abc").is_none());
        assert!(KvConfig::parse("512:xyz").is_none());
        assert!(KvConfig::parse("512:16:256:maybe").is_none());
        // Trailing fields are arity errors, not silently dropped.
        assert!(KvConfig::parse("512:16:256:cache:9").is_none());
    }

    fn gate(pages: usize, block: u32, chunk: u32) -> KvGate {
        KvGate::new(&KvConfig {
            pages,
            block_tokens: block,
            chunk_tokens: chunk,
            ..KvConfig::default()
        })
    }

    #[test]
    fn page_accounting_allocates_ceil_and_tracks_peak() {
        let mut g = gate(10, 16, 1024);
        assert_eq!(g.pages_for(1), 1);
        assert_eq!(g.pages_for(16), 1);
        assert_eq!(g.pages_for(17), 2);
        // A prompt larger than the whole pool clamps to the pool: it
        // can admit alone instead of deadlocking.
        assert_eq!(g.pages_for(1000), 10);
        g.consume(33); // 3 pages
        assert_eq!(g.pages_used(), 3);
        g.alloc(4);
        assert_eq!(g.pages_used(), 7);
        assert_eq!(g.peak_pages(), 7);
        g.free(5);
        assert_eq!(g.pages_used(), 2);
        assert_eq!(g.peak_pages(), 7, "peak is a high-water mark");
        assert!(!g.over_capacity());
        g.alloc(9);
        assert!(g.over_capacity());
    }

    #[test]
    fn admission_blocks_when_free_pages_run_out() {
        let mut g = gate(4, 16, 4096);
        assert!(g.admits(48)); // 3 pages
        g.consume(48);
        assert!(!g.admits(32), "2 pages needed, 1 free");
        assert!(g.admits(16), "1 page still fits");
        g.free(3);
        assert!(g.admits(48), "freed pages re-admit");
    }

    #[test]
    fn chunk_budget_accrues_across_ticks_for_oversized_prompts() {
        // Chunk budget 100/tick; a 250-token prompt is bigger than any
        // single chunk: it must wait until enough budget accrues
        // (Sarathi chunked prefill across ticks), not jump the gate.
        let mut g = gate(1000, 16, 100);
        assert!(!g.admits(250), "initial allotment is one chunk");
        g.tick();
        assert!(!g.admits(250), "two chunks still short");
        g.tick();
        assert!(g.admits(250), "three chunks cover the prompt");
        g.consume(250);
        assert_eq!(g.token_totals(), (250, 300));
        // Leftover budget (50) still admits a small prompt.
        assert!(g.admits(50));
        assert!(!g.admits(51));
        // Re-prefill charges eat budget without counting as admissions.
        g.charge(40);
        assert!(!g.admits(50));
        assert!(g.admits(10));
        assert_eq!(g.token_totals(), (250, 300));
    }

    #[test]
    fn prefix_index_hits_block_aligned_prefixes() {
        let mut g = gate(1000, 16, 4096);
        assert_eq!(g.prefix_lookup(100, 0.0), 0, "cold index misses");
        g.prefix_insert(100, 0.0); // indexes floor(100/16)*16 = 96
        assert_eq!(g.prefix_lookup(100, 0.0), 96);
        assert_eq!(g.prefix_lookup(200, 0.0), 96, "longest prefix ≤ own length");
        assert_eq!(g.prefix_lookup(90, 0.0), 0, "shorter prompts miss (80 < 96)");
        g.prefix_insert(64, 0.0);
        assert_eq!(g.prefix_lookup(90, 0.0), 64);
        // A fully-covered prompt still prefills at least one token.
        assert_eq!(g.prefix_lookup(96, 0.0), 95);
        let (hits, lookups) = g.prefix_stats();
        assert_eq!((hits, lookups), (4, 6));
    }

    #[test]
    fn prefix_index_lru_evicts_at_entry_budget() {
        let mut g = KvGate::new(&KvConfig {
            prefix_cache_entries: 2,
            block_tokens: 16,
            ..KvConfig::default()
        });
        g.prefix_insert(16, 0.0);
        g.prefix_insert(32, 0.0);
        assert_eq!(g.prefix_evictions(), 0, "within budget");
        // A third insert evicts the least-recently-used entry (16).
        g.prefix_insert(48, 0.0);
        assert_eq!(g.prefix_evictions(), 1);
        assert_eq!(g.prefix_lookup(17, 0.0), 0, "16 was evicted");
        // A lookup hit refreshes recency: touch 32, insert 64 → the LRU
        // victim is now 48, not 32.
        assert_eq!(g.prefix_lookup(33, 0.0), 32);
        g.prefix_insert(64, 0.0);
        assert_eq!(g.prefix_evictions(), 2);
        assert_eq!(g.prefix_lookup(49, 0.0), 32, "48 evicted, 32 kept");
        // Re-inserting an indexed length refreshes it without eviction.
        g.prefix_insert(64, 0.0);
        assert_eq!(g.prefix_evictions(), 2);
        // Degenerate budgets clamp to one entry instead of thrashing.
        assert_eq!(
            KvConfig {
                prefix_cache_entries: 0,
                ..KvConfig::default()
            }
            .normalized()
            .prefix_cache_entries,
            1
        );
    }

    #[test]
    fn prefix_cache_disabled_never_hits_or_counts() {
        let mut g = KvGate::new(&KvConfig {
            prefix_caching: false,
            ..KvConfig::default()
        });
        g.prefix_insert(100, 0.0);
        assert_eq!(g.prefix_lookup(100, 0.0), 0);
        assert_eq!(g.prefix_stats(), (0, 0));
    }

    #[test]
    fn prefix_index_ttl_expires_stale_entries() {
        let mut g = KvGate::new(&KvConfig {
            prefix_cache_ttl: Some(10.0),
            block_tokens: 16,
            ..KvConfig::default()
        });
        g.prefix_insert(32, 0.0);
        assert_eq!(g.prefix_lookup(33, 5.0), 32, "within TTL");
        // The hit at t=5 refreshed the stamp: still live at t=14.
        assert_eq!(g.prefix_lookup(33, 14.0), 32);
        // 14 + 10 < 25: expired before this lookup runs.
        assert_eq!(g.prefix_lookup(33, 25.0), 0, "stale entry expired");
        assert_eq!(g.prefix_evictions(), 1, "TTL expiry counts as eviction");
        // Insert-side expiry: an old entry vanishes when a new insert
        // arrives past its deadline, without needing a lookup.
        g.prefix_insert(64, 25.0);
        g.prefix_insert(128, 40.0);
        assert_eq!(g.prefix_evictions(), 2);
        assert_eq!(g.prefix_lookup(70, 40.0), 0, "64 expired at insert time");
        assert_eq!(g.prefix_lookup(130, 40.0), 128);
        // Non-positive TTLs normalize away instead of evicting
        // everything on sight.
        assert_eq!(
            KvConfig {
                prefix_cache_ttl: Some(0.0),
                ..KvConfig::default()
            }
            .normalized()
            .prefix_cache_ttl,
            None
        );
    }
}
