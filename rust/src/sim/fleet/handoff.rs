//! KV handoff paths: moving a stream's KV state between shards.
//!
//! Today that is the hard-outage failover (in-flight KV lost, forced
//! mid-decode re-prefill at a migration target); prefill→decode
//! disaggregation hands off through the same booking machinery.

use super::*;

impl<'a> FleetSim<'a> {

    /// Hard-outage KV loss on shard `s`: every mid-decode stream whose
    /// KV lived there must re-prefill its full context. When a
    /// migration target admits, the stream *moves* — its source slot
    /// frees now and the target is booked through the §4.3 over-commit
    /// machinery until the stretched stream ends (the forced-migration
    /// variant of the paper's Eq. 5 buffer sizing) — otherwise it
    /// re-prefills in place on the draining source. Either way the
    /// rewrite stretches exactly one inter-token gap, so token
    /// conservation (no gaps, no duplicates, order) holds by
    /// construction. Admitted-but-unresolved streams are left to the
    /// connection-draining path (their prefill re-runs implicitly).
    pub(super) fn kv_outage_failover(&mut self, s: usize, now: f64) {
        let live: Vec<usize> = self.kv_live[s].clone();
        for j in live {
            if !self.arena.resolved[j] || self.kv_release_done[j] {
                continue;
            }
            let (eligible, tbt_len) = match &self.records[j] {
                Some(r) => (r.winner == EndpointKind::Server && !r.migrated, r.tbts.len()),
                None => (false, 0),
            };
            let emitted = self.tokens_emitted(j, now);
            if !eligible || emitted == 0 || emitted > tbt_len {
                continue;
            }
            let reprefill =
                (self.server_tokens[j] as u64 + emitted as u64).min(u32::MAX as u64) as u32;
            let rate = self
                .fleet
                .batching
                .admission_tokens_per_sec()
                .expect("paged mode has an admission rate");
            // Fresh snapshot per victim: each placement is visible to
            // the next pick, spreading victims across survivors. Under
            // disaggregation a mid-decode victim can only land on a
            // decode shard — prefill shards never decode.
            let mask = self.fleet.disagg.is_some().then_some(PoolRole::Decode);
            let any_admitting = self.snapshot_views_role(mask);
            let target = if any_admitting {
                pick_reprefill_target(&self.views, |t| {
                    self.shards[t].rtt + self.reprefill_queue_delay(t, None, false, 0.0)
                })
            } else {
                None
            };
            // The lost pages leave the source ledger either way.
            let held = self.kv_pages_held[j];
            self.kv_pages_held[j] = 0;
            if held > 0 {
                if let Some(g) = self.shards[s].pool.kv_mut() {
                    g.free(held);
                }
            }
            match target {
                Some(t) => {
                    // A tracked stream (iteration-level pricing) leaves
                    // the repricing set at the forced migration: its
                    // delivered record finalizes from the repriced
                    // timeline first, then the committed tail
                    // stretches like any other failover victim. No-op
                    // for untracked streams.
                    self.finalize_stream(j, s);
                    let delta = self.shards[t].rtt
                        + self.reprefill_queue_delay(t, None, false, 0.0)
                        + reprefill as f64 / rate;
                    let old_rel = self.kv_release_at[j];
                    let done = {
                        let rec = self.records[j].as_mut().expect("eligible implies a record");
                        rec.tbts[emitted - 1] += delta;
                        self.trace.requests[j].arrival
                            + rec.ttft
                            + rec.tbts.iter().sum::<f64>()
                    };
                    if done.is_finite() {
                        self.horizon = self.horizon.max(done);
                    }
                    // The source slot frees *now* instead of at the old
                    // release time: roll back the busy seconds it will
                    // not serve and retire the stream inline (the
                    // pending release event is superseded via
                    // `kv_release_done`).
                    self.kv_release_done[j] = true;
                    self.kv_live[s].retain(|&x| x != j);
                    let sample = self.arena.pre[j]
                        .server_sample
                        .expect("server users have a sample");
                    self.shards[s].work -= sample;
                    self.shards[s].busy -= (old_rel - now).max(0.0);
                    let next = self
                        .shards[s]
                        .pool
                        .release(&self.server_cancelled, &self.server_tokens);
                    self.touch_shard(s);
                    if let Some(n) = next {
                        self.on_server_admit(n, now);
                        self.try_resolve(n, now);
                    }
                    self.record_batch(s, now);
                    // Book the target through the §4.3 machinery: the
                    // stretched tail occupies it until the new end.
                    let real_slot = self.shards[t].pool.acquire_overflow();
                    let booked = (old_rel - now).max(0.0) + delta;
                    self.shards[t].work += booked;
                    self.shards[t].migrated_in += 1;
                    self.migration_targeted += 1;
                    if let Some(g) = self.shards[t].pool.kv_mut() {
                        let pages = g.pages_for(reprefill);
                        g.alloc(pages);
                        g.charge(reprefill as u64);
                        self.kv_mig_pages[j] = pages;
                    }
                    self.touch_shard(t);
                    self.migration_booking[j] = Some((t, real_slot, booked, now));
                    self.record_batch(t, now);
                    self.push((old_rel + delta).max(now), EvKind::MigrationRelease(j));
                    self.kv_suspend_until[j] = now + delta;
                }
                None => {
                    // Nowhere to go: re-prefill in place on the
                    // draining source, which keeps serving in-flight
                    // work under connection draining.
                    let delta = reprefill as f64 / rate;
                    if self.gen_times[j].is_empty() {
                        let done = {
                            let rec =
                                self.records[j].as_mut().expect("eligible implies a record");
                            rec.tbts[emitted - 1] += delta;
                            self.trace.requests[j].arrival
                                + rec.ttft
                                + rec.tbts.iter().sum::<f64>()
                        };
                        if done.is_finite() {
                            self.horizon = self.horizon.max(done);
                        }
                    } else {
                        // Tracked stream: the stall shifts the pending
                        // generation suffix; finalization at the
                        // (superseded, later) release delivers it.
                        let rel = now - self.trace.requests[j].arrival;
                        for t in self.gen_times[j].iter_mut() {
                            if *t > rel {
                                *t += delta;
                            }
                        }
                    }
                    self.shards[s].busy += delta;
                    if let Some(g) = self.shards[s].pool.kv_mut() {
                        g.charge(reprefill as u64);
                    }
                    self.kv_suspend_until[j] = now + delta;
                    let new_rel = self.kv_release_at[j] + delta;
                    self.kv_release_at[j] = new_rel;
                    self.push(new_rel.max(now), EvKind::ServerRelease(j));
                    self.touch_shard(s);
                }
            }
            self.kv_forced_reprefills += 1;
        }
    }

}
