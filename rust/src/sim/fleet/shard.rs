//! Shard-side state: admission pools and gates, per-shard lifecycle,
//! routing (balancer picks), autoscaling transitions, outage
//! injection, and the load/batch telemetry they feed.

use super::*;

// ---------------------------------------------------------------------
// Resource pools
// ---------------------------------------------------------------------

/// Continuous-batching admission gate: prefill admission consumes a
/// prompt-token budget replenished every scheduling tick instead of a
/// slot. A prompt longer than the whole per-tick budget is admitted
/// when the tick's budget is untouched (consuming all of it), so
/// oversized prompts cannot starve behind the gate.
#[derive(Debug)]
pub(super) struct BatchGate {
    /// Prompt tokens admissible per scheduling tick.
    pub(super) budget_per_tick: u64,
    /// Remaining budget in the current tick.
    pub(super) budget_left: u64,
    /// Optional cap on concurrently decoding streams.
    pub(super) max_batch: Option<usize>,
    /// Prompt tokens actually admitted (token-budget utilization
    /// numerator).
    pub(super) admitted_tokens: u64,
    /// Budget made available so far: the initial allotment plus one
    /// `budget_per_tick` per tick (the utilization denominator).
    pub(super) capacity_tokens: u64,
}

impl BatchGate {
    pub(super) fn new(cfg: &ContinuousBatchConfig) -> BatchGate {
        let per = cfg.prefill_tokens_per_tick.max(1) as u64;
        BatchGate {
            budget_per_tick: per,
            budget_left: per,
            max_batch: cfg.max_batch,
            admitted_tokens: 0,
            capacity_tokens: per,
        }
    }

    pub(super) fn admits(&self, in_use: usize, tokens: u32) -> bool {
        if let Some(mb) = self.max_batch {
            if in_use >= mb {
                return false;
            }
        }
        let t = tokens as u64;
        let fresh = self.budget_left == self.budget_per_tick;
        t <= self.budget_left || (fresh && t > self.budget_per_tick)
    }

    pub(super) fn consume(&mut self, tokens: u32) {
        self.admitted_tokens += tokens as u64;
        self.budget_left = self.budget_left.saturating_sub(tokens as u64);
    }

    pub(super) fn tick(&mut self) {
        self.budget_left = self.budget_per_tick;
        self.capacity_tokens += self.budget_per_tick;
    }
}

/// Admission gate attached to a pool: the continuous-batching token
/// budget or the paged-KV page ledger. `None` on the pool = slot
/// semantics.
#[derive(Debug)]
pub(super) enum Gate {
    Batch(BatchGate),
    Kv(KvGate),
}

/// Build the gate matching the fleet's (normalized) batching mode.
pub(super) fn make_gate(batching: &BatchingMode) -> Option<Gate> {
    match batching {
        BatchingMode::SlotLegacy => None,
        BatchingMode::Continuous(c) => Some(Gate::Batch(BatchGate::new(c))),
        BatchingMode::PagedKv(k) => Some(Gate::Kv(KvGate::new(k))),
    }
}

/// FIFO admission pool. Under slot semantics (`gate == None`) it is a
/// (possibly unlimited) concurrency cap; under continuous batching the
/// cap is gone and a [`BatchGate`] token budget gates admission
/// instead. Cancelled entries are skipped lazily at pop time; live-entry
/// and queued-token counters are maintained incrementally (adjusted at
/// cancellation via [`Pool::cancel_queued`]) so the balancer's
/// per-arrival snapshot is O(1) per shard instead of an O(queue) rescan.
#[derive(Debug)]
pub(super) struct Pool {
    pub(super) cap: Option<usize>,
    pub(super) in_use: usize,
    /// Units of `in_use` booked by §4.3 batch-join over-commits
    /// (`acquire_overflow` past the cap, or any migrated-in join under
    /// continuous batching). Tracked separately from real slots so a
    /// spurious second over-commit release can never free a slot a real
    /// holder still occupies, and so occupancy and over-commit surface
    /// separately in [`ShardLoad`].
    pub(super) over_commit: usize,
    pub(super) queue: VecDeque<usize>,
    /// Non-cancelled entries currently in `queue`.
    pub(super) live: usize,
    /// Prompt tokens of the live queued entries — the token-backlog
    /// signal balancers, the autoscaler, and the migration planner read
    /// under continuous batching.
    pub(super) queued_tokens: u64,
    /// A frozen (cold-shard) pool queues every acquire unconditionally;
    /// nothing admits until the shard's warm-up event unfreezes it.
    /// Static fleets never freeze, so the PR-2 semantics are untouched.
    pub(super) frozen: bool,
    /// Releases that found nothing to release (a double release).
    /// Previously `saturating_sub` silently absorbed these, masking the
    /// bug as a permanent capacity leak; now they are counted (and
    /// debug-asserted) and surface in `LoadReport::release_underflows`.
    /// Always 0 on a correct event flow.
    pub(super) underflows: usize,
    /// High-water mark of `in_use`: the peak batch size under
    /// continuous batching, peak occupancy (incl. over-commit) under
    /// slots.
    pub(super) peak_in_use: usize,
    /// Admission gate: continuous-batching token budget or paged-KV
    /// page ledger (`None` = slot semantics).
    pub(super) gate: Option<Gate>,
}

impl Pool {
    pub(super) fn new(cap: Option<usize>) -> Pool {
        Pool {
            cap,
            in_use: 0,
            over_commit: 0,
            queue: VecDeque::new(),
            live: 0,
            queued_tokens: 0,
            frozen: false,
            underflows: 0,
            peak_in_use: 0,
            gate: None,
        }
    }

    /// A cold shard's pool: queues everything until unfrozen.
    pub(super) fn new_frozen(cap: Option<usize>) -> Pool {
        Pool {
            frozen: true,
            ..Pool::new(cap)
        }
    }

    /// Attach (or not) a continuous-batching gate.
    pub(super) fn with_gate(self, gate: Option<BatchGate>) -> Pool {
        self.with_gate_kind(gate.map(Gate::Batch))
    }

    /// Attach (or not) an admission gate of either kind.
    pub(super) fn with_gate_kind(mut self, gate: Option<Gate>) -> Pool {
        self.gate = gate;
        self
    }

    /// The paged-KV gate, if this pool carries one.
    pub(super) fn kv(&self) -> Option<&KvGate> {
        match &self.gate {
            Some(Gate::Kv(g)) => Some(g),
            _ => None,
        }
    }

    pub(super) fn kv_mut(&mut self) -> Option<&mut KvGate> {
        match &mut self.gate {
            Some(Gate::Kv(g)) => Some(g),
            _ => None,
        }
    }

    /// Whether an arrival with `tokens` prompt tokens can admit right
    /// now (ignoring the frozen flag, which callers check first).
    pub(super) fn admits_now(&self, tokens: u32) -> bool {
        match &self.gate {
            Some(Gate::Batch(g)) => g.admits(self.in_use, tokens),
            Some(Gate::Kv(g)) => g.admits(tokens),
            None => match self.cap {
                None => true,
                Some(cap) => self.in_use < cap,
            },
        }
    }

    /// Consume one admission: bump `in_use` (and the token budget or
    /// page ledger under a gate) and track the peak.
    pub(super) fn admit_now(&mut self, tokens: u32) {
        self.in_use += 1;
        if self.in_use > self.peak_in_use {
            self.peak_in_use = self.in_use;
        }
        match &mut self.gate {
            Some(Gate::Batch(g)) => g.consume(tokens),
            Some(Gate::Kv(g)) => g.consume(tokens),
            None => {}
        }
    }

    /// Checked release of one `in_use` unit: a double release is
    /// recorded (and debug-asserted) instead of being silently clamped
    /// into a permanent capacity leak.
    pub(super) fn dec_in_use(&mut self) {
        debug_assert!(self.in_use > 0, "pool release with nothing in use");
        if self.in_use == 0 {
            self.underflows += 1;
        } else {
            self.in_use -= 1;
        }
    }

    /// Try to acquire; queues and returns false when full, frozen, or
    /// out of token budget. Unlimited pools admit immediately but still
    /// count `in_use`, so balancers see real in-service load even
    /// without a slot cap.
    ///
    /// Admission is FIFO: under a token gate a live entry may be queued
    /// while budget remains (its prompt didn't fit the tick), and a new
    /// small arrival must queue behind it rather than jump it. Slot
    /// pools never have a live queue alongside spare capacity (releases
    /// transfer), so the guard is gated to batch mode and legacy
    /// behavior is untouched.
    pub(super) fn acquire(&mut self, i: usize, tokens: u32) -> bool {
        let fifo_blocked = self.gate.is_some() && self.live > 0;
        if !self.frozen && !fifo_blocked && self.admits_now(tokens) {
            self.admit_now(tokens);
            return true;
        }
        self.queue.push_back(i);
        self.live += 1;
        self.queued_tokens += tokens as u64;
        false
    }

    /// Admit the next live queued entry if the pool has spare capacity
    /// (or token budget) and is not frozen — the unit is newly
    /// consumed, unlike the slot-transfer path of [`Pool::release`].
    /// `tokens[j]` is request `j`'s prompt length.
    pub(super) fn try_admit(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.frozen {
            return None;
        }
        loop {
            let &j = self.queue.front()?;
            if cancelled[j] {
                // Cancelled entries left `live` (and `queued_tokens`)
                // at cancellation time; just drop the dead slot.
                self.queue.pop_front();
                continue;
            }
            if !self.admits_now(tokens[j]) {
                return None;
            }
            self.queue.pop_front();
            self.live = self.live.saturating_sub(1);
            self.queued_tokens = self.queued_tokens.saturating_sub(tokens[j] as u64);
            self.admit_now(tokens[j]);
            return Some(j);
        }
    }

    /// Release one unit; returns the next queued request to admit, if
    /// any. Under slot semantics the unit *transfers* to the next live
    /// queued entry; under a batch gate the departing stream only frees
    /// batch headroom and any admission stays token-gated.
    pub(super) fn release(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.gate.is_some() {
            self.dec_in_use();
            return self.try_admit(cancelled, tokens);
        }
        while let Some(j) = self.queue.pop_front() {
            if !cancelled[j] {
                self.live = self.live.saturating_sub(1);
                self.queued_tokens = self.queued_tokens.saturating_sub(tokens[j] as u64);
                return Some(j);
            }
        }
        self.dec_in_use();
        None
    }

    /// A queued entry was cancelled (its lazily-skipped queue slot is
    /// now dead): keep the live count and token backlog in sync.
    pub(super) fn cancel_queued(&mut self, tokens: u32) {
        self.live = self.live.saturating_sub(1);
        self.queued_tokens = self.queued_tokens.saturating_sub(tokens as u64);
    }

    /// Live (non-cancelled) queue length — the balancer's view.
    pub(super) fn live_queued(&self) -> usize {
        self.live
    }

    /// Prompt tokens queued for admission (live entries only).
    pub(super) fn queued_prompt_tokens(&self) -> u64 {
        self.queued_tokens
    }

    /// Occupy one unit for a §4.3 migrated-in stream. Under slot
    /// semantics it takes a real slot when capacity is spare and
    /// otherwise joins the running batch over-capacity; under
    /// continuous batching it always joins the batch (the handoff time
    /// was already committed, so the stream cannot queue — neither the
    /// token budget nor `max_batch` applies). Returns whether a real
    /// slot was taken, which decides the matching release path.
    pub(super) fn acquire_overflow(&mut self) -> bool {
        let real = match (&self.gate, self.cap) {
            (Some(_), _) => false,
            (None, Some(cap)) => self.in_use < cap,
            (None, None) => true,
        };
        if !real {
            self.over_commit += 1;
        }
        self.in_use += 1;
        if self.in_use > self.peak_in_use {
            self.peak_in_use = self.in_use;
        }
        real
    }

    /// Release an over-capacity (batch-join) unit. Real slots may have
    /// freed *underneath* the over-commit in the meantime (their release
    /// saw an empty queue and simply decremented), leaving this unit
    /// load-bearing — so after the decrement, any spare capacity admits
    /// the next live queued entry exactly like a real-slot release would
    /// have. Skipping that admission would strand the queue forever: no
    /// later release event exists on the shard.
    ///
    /// A release with no over-commit outstanding is a double release:
    /// it is refused (counted in `underflows`) instead of decrementing
    /// `in_use`, which would free a slot a real holder still occupies —
    /// the accounting bug this PR's sweep fixed.
    pub(super) fn release_overflow(&mut self, cancelled: &[bool], tokens: &[u32]) -> Option<usize> {
        if self.over_commit == 0 {
            debug_assert!(false, "over-commit release with no over-commit outstanding");
            self.underflows += 1;
            return None;
        }
        self.over_commit -= 1;
        self.dec_in_use();
        self.try_admit(cancelled, tokens)
    }

    /// Remove every live queued entry (outage re-routing); cancelled
    /// entries are dropped on the way. Leaves the queue empty.
    pub(super) fn drain_queue(&mut self, cancelled: &[bool]) -> Vec<usize> {
        let mut live = Vec::with_capacity(self.live);
        while let Some(j) = self.queue.pop_front() {
            if !cancelled[j] {
                live.push(j);
            }
        }
        self.live = 0;
        self.queued_tokens = 0;
        live
    }

    /// Replenish the token budget at a scheduling tick (no-op for slot
    /// pools). An *idle* tick — budget untouched and nothing queued —
    /// offered no usable capacity and accrues none, so
    /// `token_budget_utilization` measures budget offered while there
    /// was work, not the trace's idle tail.
    pub(super) fn tick(&mut self) {
        match &mut self.gate {
            Some(Gate::Batch(g)) => {
                let idle = g.budget_left == g.budget_per_tick && self.live == 0;
                if !idle {
                    g.tick();
                }
            }
            Some(Gate::Kv(g)) => {
                // The KV chunk budget accrues (never resets), so only
                // ticks with queued prefill work offer usable capacity;
                // accruing while nothing waits would let a later burst
                // admit unboundedly in one tick.
                if self.live > 0 {
                    g.tick();
                }
            }
            None => {}
        }
    }

    /// (admitted, capacity) prompt-token totals of the gate; zeros for
    /// slot pools.
    pub(super) fn token_totals(&self) -> (u64, u64) {
        match &self.gate {
            Some(Gate::Batch(g)) => (g.admitted_tokens, g.capacity_tokens),
            Some(Gate::Kv(g)) => g.token_totals(),
            None => (0, 0),
        }
    }
}

/// One server shard: a bounded slot pool plus its load accounting and
/// autoscaling lifecycle (static fleets stay `Warm` forever).
pub(super) struct ShardState {
    pub(super) pool: Pool,
    /// Extra RTT (seconds) this shard adds to every first token it serves
    /// (offset relative to the scenario's base server endpoint).
    pub(super) rtt: f64,
    /// Outstanding estimated service seconds: pre-drawn prefill samples
    /// of requests assigned to this shard that are queued or still hold
    /// a slot (retired at `ServerRelease`, or at resolve for entries
    /// that never held one). The `LeastWork` balancer's signal.
    pub(super) work: f64,
    pub(super) busy: f64,
    /// Seconds of §4.3 batch-join occupancy held *above* the shard's
    /// slot capacity (over-commit bookings; real-slot bookings land in
    /// `busy`). Reported separately from `busy` so utilization stays a
    /// within-capacity ratio.
    pub(super) overcommit_seconds: f64,
    pub(super) delays: Vec<f64>,
    pub(super) admitted: usize,
    /// §4.3 migrated streams routed into this shard's pool
    /// (shard-targeted migration only).
    pub(super) migrated_in: usize,
    /// Which phase pool the shard serves (always `Unified` outside
    /// disaggregation; routing surfaces mask candidates by this).
    pub(super) role: PoolRole,
    /// Handed-off streams this (decode) shard received via prefill →
    /// decode KV transfer. Disjoint from `migrated_in`.
    pub(super) handoff_in: usize,
    /// Last batch size recorded in the batch timeline (dedupes
    /// consecutive identical samples); `None` before the first sample.
    pub(super) last_batch: Option<usize>,
    /// Cold → Warm → Draining → Retired under autoscaling (outages force
    /// Draining mid-run).
    pub(super) phase: LifecyclePhase,
    /// Absolute creation time (the first arrival for initial shards), the
    /// start of this shard's shard-seconds accrual.
    pub(super) created_at: f64,
    /// When a cold shard finishes loading (drives the all-cold routing
    /// fallback); 0.0 for shards created warm.
    pub(super) ready_at: f64,
    /// Absolute retirement time; `None` while the shard still accrues
    /// shard-seconds.
    pub(super) retired_at: Option<f64>,
}

impl ShardState {
    pub(super) fn new(pool: Pool, rtt: f64, phase: LifecyclePhase, created_at: f64, ready_at: f64) -> Self {
        ShardState {
            pool,
            rtt,
            work: 0.0,
            busy: 0.0,
            overcommit_seconds: 0.0,
            delays: Vec::new(),
            admitted: 0,
            migrated_in: 0,
            role: PoolRole::Unified,
            handoff_in: 0,
            last_batch: None,
            phase,
            created_at,
            ready_at,
            retired_at: None,
        }
    }
}

impl<'a> FleetSim<'a> {

    /// Rebuild the reusable per-shard snapshot buffer (`self.views`);
    /// returns whether any shard currently admits new work.
    pub(super) fn snapshot_views(&mut self) -> bool {
        self.snapshot_views_role(None)
    }

    /// Role-masked snapshot: with `Some(role)`, shards of any other
    /// role are flagged non-admitting so balancers and re-prefill
    /// targeting confine themselves to one pool. `None` reproduces the
    /// unmasked snapshot bit-for-bit (the unified path).
    pub(super) fn snapshot_views_role(&mut self, role: Option<PoolRole>) -> bool {
        self.views.clear();
        let mut any_admitting = false;
        for sh in &self.shards {
            let admitting =
                sh.phase == LifecyclePhase::Warm && role.map_or(true, |r| sh.role == r);
            any_admitting |= admitting;
            self.views.push(ShardView {
                in_use: sh.pool.in_use,
                queued: sh.pool.live_queued(),
                slots: sh.pool.cap,
                work: sh.work,
                queued_tokens: sh.pool.queued_prompt_tokens(),
                admitting,
            });
        }
        any_admitting
    }

    /// The routing mask for work that must stay on shard `s`'s pool:
    /// `Some(role)` under disaggregation, `None` (no masking — the
    /// byte-identical historical path) otherwise.
    pub(super) fn role_mask_of(&self, s: usize) -> Option<PoolRole> {
        if self.fleet.disagg.is_some() {
            Some(self.shards[s].role)
        } else {
            None
        }
    }

    /// Decode-gap multiplier for a stream joining shard `s`'s batch
    /// right now (the stream itself already counted in `in_use`). 1.0
    /// under slot semantics — legacy streams are never repriced.
    pub(super) fn batch_slowdown(&self, s: usize) -> f64 {
        match self.fleet.batching {
            BatchingMode::Continuous(c) => c.curve.slowdown(self.shards[s].pool.in_use),
            BatchingMode::PagedKv(k) => k.curve.slowdown(self.shards[s].pool.in_use),
            BatchingMode::SlotLegacy => 1.0,
        }
    }

    /// Whether this run re-prices running decodes on batch change:
    /// iteration-level pricing under a gated batching mode. Slot-legacy
    /// streams are never repriced regardless of the pricing mode.
    pub(super) fn reprice_active(&self) -> bool {
        self.fleet.pricing == PricingMode::IterationLevel && self.fleet.batching.batched()
    }

    /// Whether `ServerRelease` events can be superseded and must pass
    /// the timestamp guard: paged KV stretches releases at preemption
    /// and failover, iteration-level repricing moves them on any batch
    /// change.
    pub(super) fn release_guard_active(&self) -> bool {
        self.fleet.batching.is_paged() || self.reprice_active()
    }

    /// Append a batch-size sample for shard `s` if the size changed
    /// (continuous batching only; legacy runs record nothing, keeping
    /// their load reports byte-identical). Under iteration-level
    /// pricing a size change is exactly the repricing trigger: the
    /// slowdown curve reads only the batch *size*, so same-size
    /// composition churn (one stream leaves as another admits) is a
    /// semantic no-op and is skipped by the dedupe.
    pub(super) fn record_batch(&mut self, s: usize, now: f64) {
        if !self.fleet.batching.batched() {
            return;
        }
        let batch = self.shards[s].pool.in_use;
        if self.shards[s].last_batch == Some(batch) {
            return;
        }
        self.shards[s].last_batch = Some(batch);
        self.batch_samples.push(BatchSample {
            time: now,
            shard: s,
            batch,
        });
        if self.reprice_active() {
            self.reprice_shard(s, now);
        }
    }

    /// Balance server-bound request `i` onto a shard, apply any
    /// configured per-shard degradation to its pre-drawn sample, and
    /// book its work estimate. With one shard the balancer (and its RNG
    /// stream) is bypassed entirely, preserving byte-identical K=1
    /// replays. Cold, draining, and retired shards are flagged
    /// non-admitting; should every shard be non-admitting (unreachable
    /// while the autoscaler keeps `min_shards ≥ 1` warm, but handled
    /// defensively), the request joins the cold shard that becomes
    /// ready soonest.
    pub(super) fn assign_shard(&mut self, i: usize, now: f64) -> usize {
        // Disaggregated fleets balance arrivals across the *prefill*
        // pool only (decode shards receive work via handoff, never at
        // arrival); unified fleets snapshot unmasked, byte-identically.
        let arrival_mask = self
            .fleet
            .disagg
            .is_some()
            .then_some(PoolRole::Prefill);
        let s = if self.shards.len() == 1 {
            0
        } else if self.shard_index.is_some() {
            // JSQ / least-work: answer the argmin from the incremental
            // index instead of snapshotting and rescanning all K shards.
            // Neither balancer consumes randomness, so skipping
            // `Balancer::pick` leaves the fleet balancer stream — and
            // therefore every other draw — byte-identical. (Never built
            // under disaggregation, where picks must be role-masked.)
            self.pick_indexed()
        } else {
            let any_admitting = self.snapshot_views_role(arrival_mask);
            if any_admitting {
                let pick = self.balancer.pick(&self.views, &mut self.brng);
                assert!(
                    pick < self.shards.len(),
                    "balancer {} violated its contract: picked shard {pick} of {}",
                    self.balancer.name(),
                    self.shards.len()
                );
                debug_assert!(
                    self.views[pick].admitting,
                    "balancer {} routed to a non-admitting shard {pick}",
                    self.balancer.name()
                );
                pick
            } else {
                self.earliest_ready_shard()
            }
        };
        self.shard_of[i] = Some(s);
        let mut sample = self.arena.pre[i]
            .server_sample
            .expect("server users have a sample");
        // Per-shard degradation: landing on a faulty shard may multiply
        // the pre-drawn prefill sample by an extra spike (drawn from the
        // dedicated fault stream). Applied here — before the work
        // booking, the first-token probe, or the resolve step read the
        // sample — so every consumer sees the degraded value, the
        // LeastWork/queue-delay oracles included.
        if let Some(&Some(f)) = self.fleet.shard_faults.get(s) {
            if self.frng.chance(f.spike_prob) {
                let base = sample;
                sample *= self.frng.lognormal(f.spike_scale.max(1e-12).ln(), 0.5);
                self.arena.pre[i].server_sample = Some(sample);
                self.arena.base_sample[i] = Some(base);
            }
        }
        sample = self.apply_prefix_cache(i, s, sample, now);
        self.shards[s].work += sample;
        self.touch_shard(s);
        s
    }

    /// Paged-KV prefix-cache lookup for request `i` landing on shard
    /// `s`: a hit scales the pre-drawn prefill sample down to the
    /// uncached fraction and shrinks the admission charge
    /// (`server_tokens`) to the uncached suffix. Deterministic and
    /// RNG-free; a no-op (returning `sample` unchanged) outside paged
    /// mode, so other modes stay byte-identical. Returns the sample
    /// every downstream consumer should see.
    pub(super) fn apply_prefix_cache(&mut self, i: usize, s: usize, sample: f64, now: f64) -> f64 {
        if !self.fleet.batching.is_paged() {
            return sample;
        }
        let len = self.prompt_tokens[i];
        let cached = match self.shards[s].pool.kv_mut() {
            Some(g) => g.prefix_lookup(len, now),
            None => 0,
        };
        if cached == 0 {
            return sample;
        }
        // Remember the full-prefill draw: an outage re-route restores
        // it (the cached prefix lived on this shard, not the stream)
        // and re-runs the lookup against the new home's index.
        if self.arena.base_sample[i].is_none() {
            self.arena.base_sample[i] = Some(sample);
        }
        let scaled = sample * (1.0 - cached as f64 / len as f64);
        self.arena.pre[i].server_sample = Some(scaled);
        self.server_tokens[i] = (len - cached).max(1);
        scaled
    }

    /// O(dirty · log K) shard pick through the incremental index: flush
    /// every shard marked stale since the last pick (recomputing its
    /// leaf from live pool/work/phase state — exactly what a
    /// [`ShardView`] snapshot would report), then read the tournament
    /// root. A non-admitting root means no shard admits, the same
    /// degraded path the scan balancers take. Debug builds re-derive the
    /// pick from a full snapshot + linear scan and assert equality.
    pub(super) fn pick_indexed(&mut self) -> usize {
        let jsq = self.fleet.balancer == BalancerKind::JoinShortestQueue;
        let idx = self
            .shard_index
            .as_mut()
            .expect("indexed pick requires an index");
        while let Some(s) = idx.pop_dirty() {
            let sh = &self.shards[s];
            let admitting = sh.phase == LifecyclePhase::Warm;
            // JSQ orders on outstanding = in_use + queued; counts are
            // tiny relative to 2^53, so the f64 key orders identically.
            let key = if jsq {
                (sh.pool.in_use + sh.pool.live_queued()) as f64
            } else {
                sh.work
            };
            idx.update(s, admitting, key);
        }
        let root = idx.root();
        let pick = if root.admitting {
            root.shard
        } else {
            self.earliest_ready_shard()
        };
        #[cfg(debug_assertions)]
        {
            use crate::sim::balancer::argmin_admitting;
            let any_admitting = self.snapshot_views();
            assert_eq!(
                any_admitting, root.admitting,
                "shard index admitting flag diverged from the snapshot"
            );
            if any_admitting {
                let linear = if jsq {
                    argmin_admitting(&self.views, |a, b| a.outstanding() < b.outstanding())
                } else {
                    argmin_admitting(&self.views, |a, b| {
                        a.work.total_cmp(&b.work) == Ordering::Less
                    })
                };
                assert_eq!(
                    pick,
                    linear,
                    "shard index diverged from the linear {} scan",
                    self.fleet.balancer.label()
                );
            }
        }
        pick
    }

    /// The cold shard with the earliest warm-up time (ties to the lowest
    /// index); degrades to the first non-retired shard — never a retired
    /// pool, which must take no new work — when nothing is even cold.
    pub(super) fn earliest_ready_shard(&self) -> usize {
        let mut best: Option<usize> = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if sh.phase != LifecyclePhase::Cold {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => sh.ready_at.total_cmp(&self.shards[b].ready_at) == Ordering::Less,
            };
            if better {
                best = Some(i);
            }
        }
        best.unwrap_or_else(|| {
            // `maybe_retire` keeps at least one shard non-retired, so
            // this position exists whenever the fleet has run at all.
            self.shards
                .iter()
                .position(|sh| sh.phase != LifecyclePhase::Retired)
                .unwrap_or(0)
        })
    }

    /// One autoscaler evaluation: snapshot the fleet, ask the policy,
    /// clamp the action to `[min_shards, max_shards]`, and apply it.
    /// Unified fleets evaluate the whole shard vector (the historical
    /// path, byte-identical); disaggregated fleets evaluate each
    /// configured pool independently against role-filtered statuses —
    /// prefill first, then decode, so the decision order (and every
    /// `arng` draw) is deterministic.
    pub(super) fn autoscale_eval(&mut self, now: f64) {
        if self.fleet.disagg.is_none() {
            let cfg = *self.autoscale.as_ref().expect("eval implies config");
            if self.scaler.is_some() {
                self.autoscale_eval_pool(now, None, cfg);
            }
            return;
        }
        if let Some(cfg) = self.autoscale {
            if self.scaler.is_some() {
                self.autoscale_eval_pool(now, Some(PoolRole::Prefill), cfg);
            }
        }
        if let Some(cfg) = self.decode_autoscale {
            if self.decode_scaler.is_some() {
                self.autoscale_eval_pool(now, Some(PoolRole::Decode), cfg);
            }
        }
    }

    /// Evaluate one pool's scaling policy. `role == None` is the
    /// unified fleet (all shards, the prefill scaler pair); `Some(r)`
    /// restricts both the statuses the policy sees and the shards
    /// scale-out/-in may touch to role `r`. `ScaleAction` carries only
    /// counts, so the filtered view composes with the role-aware
    /// apply paths without index translation.
    fn autoscale_eval_pool(&mut self, now: f64, role: Option<PoolRole>, cfg: AutoscaleConfig) {
        let statuses: Vec<ShardStatus> = self
            .shards
            .iter()
            .filter(|sh| role.map_or(true, |r| sh.role == r))
            .map(|sh| ShardStatus {
                view: ShardView {
                    in_use: sh.pool.in_use,
                    queued: sh.pool.live_queued(),
                    slots: sh.pool.cap,
                    work: sh.work,
                    queued_tokens: sh.pool.queued_prompt_tokens(),
                    admitting: sh.phase == LifecyclePhase::Warm,
                },
                phase: sh.phase,
            })
            .collect();
        let view = FleetView {
            now,
            shards: &statuses,
            slots_per_shard: self.fleet.server_slots,
            min_shards: cfg.min_shards,
            max_shards: cfg.max_shards,
            prefill_tokens_per_sec: self.fleet.batching.admission_tokens_per_sec(),
        };
        let scaler = match role {
            Some(PoolRole::Decode) => self.decode_scaler.as_mut(),
            _ => self.scaler.as_mut(),
        };
        let action = scaler
            .expect("eval implies a scaling policy")
            .evaluate(&view, &mut self.arng);
        let pool_role = role.unwrap_or(PoolRole::Unified);
        match action {
            ScaleAction::Hold => {}
            ScaleAction::ScaleOut { shards } => self.scale_out(shards, now, &cfg, pool_role),
            ScaleAction::ScaleIn { shards } => self.scale_in(shards, now, &cfg, pool_role),
        }
    }

    /// Provision up to `n` cold shards of role `role`, keeping the
    /// pool's *paid-for* fleet (everything short of retired — draining
    /// victims still bill shard-seconds) within `max_shards`. Each new
    /// shard admits nothing until its load-time delay — from the
    /// configured `ColdStartSpec` — elapses. Unified fleets pass
    /// `PoolRole::Unified` and count every shard, the historical
    /// behavior; disaggregated pools count and create only their own.
    pub(super) fn scale_out(&mut self, n: usize, now: f64, cfg: &AutoscaleConfig, role: PoolRole) {
        let paid_for = self
            .shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired && s.role == role)
            .count();
        let room = cfg.max_shards.saturating_sub(paid_for);
        for _ in 0..n.min(room) {
            let ready = now + cfg.cold_start.delay();
            let idx = self.shards.len();
            // New replicas are homogeneous (no extra RTT) and share the
            // base server profile (and the fleet's batching mode, with
            // a fresh gate — a new shard starts with an empty KV pool
            // and a cold prefix index).
            let gate = make_gate(&self.fleet.batching);
            let mut sh = ShardState::new(
                Pool::new_frozen(self.pool_cap).with_gate_kind(gate),
                0.0,
                LifecyclePhase::Cold,
                now,
                ready,
            );
            sh.role = role;
            self.shards.push(sh);
            self.kv_live.push(Vec::new());
            self.decode_live.push(Vec::new());
            self.server_endpoints.push(self.scenario.server.clone());
            self.scale_events.push(ScaleEvent {
                time: now,
                shard: idx,
                kind: ScaleEventKind::ScaleOut,
            });
            self.push(ready, EvKind::ShardWarm(idx));
        }
        // The index's leaf capacity is sized to the shard count: rebuild
        // it all-dirty, so the next pick flushes every shard (including
        // the new cold ones) from live state.
        if self.shard_index.is_some() {
            self.shard_index = Some(ShardIndex::new(self.shards.len()));
        }
        self.record_timeline(now);
    }

    /// Drain up to `n` warm shards of role `role`, never dropping below
    /// `min_shards` warm in that pool (so the pool's balancer always
    /// has an admitting candidate). The victim is the warm shard with
    /// the least outstanding work; ties drain the newest shard first.
    pub(super) fn scale_in(&mut self, n: usize, now: f64, cfg: &AutoscaleConfig, role: PoolRole) {
        for _ in 0..n {
            let warm: Vec<usize> = self
                .shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.phase == LifecyclePhase::Warm && s.role == role)
                .map(|(i, _)| i)
                .collect();
            if warm.len() <= cfg.min_shards.max(1) {
                break;
            }
            let mut victim = warm[0];
            for &i in &warm[1..] {
                // Least outstanding estimated service seconds (the same
                // signal LeastWork balances on); exact ties — typically
                // idle shards at 0.0 — drain the newest first.
                match self.shards[i].work.total_cmp(&self.shards[victim].work) {
                    Ordering::Less => victim = i,
                    Ordering::Equal if i > victim => victim = i,
                    _ => {}
                }
            }
            self.shards[victim].phase = LifecyclePhase::Draining;
            self.touch_shard(victim);
            self.scale_events.push(ScaleEvent {
                time: now,
                shard: victim,
                kind: ScaleEventKind::DrainStart,
            });
            // An already-empty victim retires immediately.
            self.maybe_retire(victim, now);
        }
        self.record_timeline(now);
    }

    /// A cold shard finished loading: unfreeze its pool, join the
    /// balanced set, and admit anything already queued on it.
    pub(super) fn warm_shard(&mut self, s: usize, now: f64) {
        if self.shards[s].phase != LifecyclePhase::Cold {
            return;
        }
        self.shards[s].phase = LifecyclePhase::Warm;
        self.shards[s].pool.frozen = false;
        self.touch_shard(s);
        self.cold_start_seconds += (now - self.shards[s].created_at).max(0.0);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::WarmUp,
        });
        self.record_timeline(now);
        while let Some(j) = self
            .shards[s]
            .pool
            .try_admit(&self.server_cancelled, &self.server_tokens)
        {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
    }

    /// A draining shard retires once its last admission released and no
    /// live entry remains queued; retirement stops shard-seconds accrual
    /// (and drops the shard from the timeline's provisioned count).
    ///
    /// The **last** non-retired replica never retires: with every other
    /// shard gone (an outage on a K=1 fleet, or a fleet-wide failure),
    /// future arrivals still have to land somewhere, so the survivor
    /// keeps draining — and billing shard-seconds — to the end of the
    /// run instead of serving traffic "after" retirement (which would
    /// put busy-seconds past its lifetime and push utilization over 1).
    /// Autoscaler scale-in always leaves `min_shards ≥ 1` warm, so this
    /// guard never fires on the PR-3 paths.
    pub(super) fn maybe_retire(&mut self, s: usize, now: f64) {
        let others_alive = self
            .shards
            .iter()
            .enumerate()
            .any(|(i, sh)| i != s && sh.phase != LifecyclePhase::Retired);
        if !others_alive {
            return;
        }
        let sh = &mut self.shards[s];
        let drained = sh.phase == LifecyclePhase::Draining
            && sh.pool.in_use == 0
            && sh.pool.live_queued() == 0;
        if !drained {
            return;
        }
        sh.phase = LifecyclePhase::Retired;
        sh.retired_at = Some(now);
        self.touch_shard(s);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::Retire,
        });
        self.record_timeline(now);
    }

    /// Injected failure: force shard `s` into Draining, re-route its
    /// queued streams, and let in-flight admissions finish (connection
    /// draining) before the shard retires. Idempotent by construction —
    /// a shard already Draining (e.g. an autoscaler scale-in victim) or
    /// Retired is left untouched, so an outage racing a drain can never
    /// double-retire or double-bill shard-seconds.
    pub(super) fn inject_outage(&mut self, s: usize, now: f64) {
        if s >= self.shards.len()
            || matches!(
                self.shards[s].phase,
                LifecyclePhase::Draining | LifecyclePhase::Retired
            )
        {
            return;
        }
        // A cold victim's pending warm-up becomes a no-op (`warm_shard`
        // guards on phase); unfreeze the pool so drain semantics — serve
        // whatever cannot be re-routed — still apply.
        self.shards[s].phase = LifecyclePhase::Draining;
        self.shards[s].pool.frozen = false;
        self.touch_shard(s);
        self.scale_events.push(ScaleEvent {
            time: now,
            shard: s,
            kind: ScaleEventKind::Outage,
        });
        let victims = self.shards[s].pool.drain_queue(&self.server_cancelled);
        for j in victims {
            self.requeue(j, s, now);
        }
        // KV-aware hard failover: in paged mode the dead shard's
        // in-flight KV is lost — every mid-decode stream it was serving
        // must re-prefill, at a migration target when one admits
        // (forced §4.3 migration) or in place on the draining source
        // otherwise.
        if self.fleet.batching.is_paged() {
            self.kv_outage_failover(s, now);
        }
        // Single-shard corner: victims with nowhere to go stayed on the
        // draining shard — admit what spare capacity allows so the run
        // always terminates (a drained-but-queued cold pool would
        // otherwise never grant).
        while let Some(j) = self
            .shards[s]
            .pool
            .try_admit(&self.server_cancelled, &self.server_tokens)
        {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
        self.record_timeline(now);
        self.maybe_retire(s, now);
    }

    /// Re-route a queued (never-admitted) stream off a failed shard —
    /// the token-level view of "migrate the dead shard's pending work".
    /// The placement follows the fleet's migration-targeting mode:
    /// least-work-with-estimate under `ShardTargeted` (victims spread
    /// across survivors, each placement visible to the next), the first
    /// admitting shard under `BaseEndpoint` (the paper's "one server
    /// target" view — every victim piles onto the same replacement).
    /// With no admitting shard anywhere the victim joins the
    /// soonest-ready cold shard; with no live alternative at all it
    /// stays on the draining source, which serves out its queue.
    pub(super) fn requeue(&mut self, j: usize, from: usize, now: f64) {
        let sample = self.arena.pre[j]
            .server_sample
            .expect("server users have a sample");
        // A queued (never-admitted) stream is prefill-side work: under
        // disaggregation it may only move within the dead shard's own
        // pool. Unified fleets pass no mask (byte-identical).
        let mask = self.role_mask_of(from);
        let any_admitting = self.snapshot_views_role(mask);
        let target = if any_admitting {
            match self.fleet.migration_targeting {
                MigrationTargeting::ShardTargeted => {
                    pick_reprefill_target(&self.views, |i| {
                        self.shards[i].rtt + self.reprefill_queue_delay(i, None, false, 0.0)
                    })
                    .expect("an admitting shard exists")
                }
                MigrationTargeting::BaseEndpoint => self
                    .views
                    .iter()
                    .position(|v| v.admitting)
                    .expect("an admitting shard exists"),
            }
        } else {
            let cold = self.earliest_ready_shard();
            if self.shards[cold].phase == LifecyclePhase::Cold {
                cold
            } else {
                from
            }
        };
        self.shard_of[j] = Some(target);
        self.shards[from].work -= sample;
        self.touch_shard(from);
        // A spike drawn from the dead shard's fault belongs to that
        // shard, not the stream: moving to a new home restores the
        // pre-fault draw and rolls the *target's* fault instead (all
        // from the fault stream, so healthy configs are untouched).
        let mut new_sample = sample;
        if target != from {
            if let Some(base) = self.arena.base_sample[j] {
                new_sample = base;
                self.arena.base_sample[j] = None;
            }
            if let Some(&Some(f)) = self.fleet.shard_faults.get(target) {
                if self.frng.chance(f.spike_prob) {
                    let base = new_sample;
                    new_sample *= self.frng.lognormal(f.spike_scale.max(1e-12).ln(), 0.5);
                    self.arena.base_sample[j] = Some(base);
                }
            }
            self.arena.pre[j].server_sample = Some(new_sample);
            // The cached prefix lived on the dead shard: reset the
            // admission charge to the full prompt, then consult the new
            // home's own index (paged mode only; no-ops otherwise).
            self.server_tokens[j] = self.prompt_tokens[j];
            new_sample = self.apply_prefix_cache(j, target, new_sample, now);
            self.outage_requeues += 1;
        }
        self.shards[target].work += new_sample;
        let tokens = self.server_tokens[j];
        if self.shards[target].pool.acquire(j, tokens) {
            self.on_server_admit(j, now);
            self.try_resolve(j, now);
        }
        self.touch_shard(target);
    }

    /// Append a shard-count sample if the counts changed since the last
    /// one (evaluations that change nothing record nothing).
    pub(super) fn record_timeline(&mut self, now: f64) {
        let warm = self
            .shards
            .iter()
            .filter(|s| s.phase == LifecyclePhase::Warm)
            .count();
        // "Provisioned" is capacity still being paid for — everything
        // short of Retired — so integrating the timeline agrees with
        // `shard_seconds` (a draining shard bills until its last stream
        // ends), and scale-out headroom uses the same count, so this
        // never exceeds `max_shards`.
        let provisioned = self
            .shards
            .iter()
            .filter(|s| s.phase != LifecyclePhase::Retired)
            .count();
        if let Some(last) = self.timeline.last() {
            if last.warm == warm && last.provisioned == provisioned {
                return;
            }
        }
        self.timeline.push(ShardCountSample {
            time: now,
            warm,
            provisioned,
        });
    }

}
