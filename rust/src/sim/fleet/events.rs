//! Event payload and the fleet's discrete-event main loop.
//!
//! [`EvKind`] is the queue payload; the `(time, seq)` total order and
//! both queue backends live in [`crate::sim::event_queue`]. `run`
//! drains the queue to completion and assembles the `FleetOutcome`.

use super::*;

// ---------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------
//
// The queue itself — `(time, seq)` total ordering, wheel and heap
// backends — lives in `crate::sim::event_queue`; the fleet only defines
// its event payload.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) enum EvKind {
    Arrival(usize),
    /// Request `.0`'s server stream ended: its shard's admission slot
    /// frees (admit the next queued request) and its work estimate
    /// retires from the shard.
    ServerRelease(usize),
    /// The device frees; grant it to the next queued request.
    DeviceRelease,
    /// The server produced its first token while the request was still
    /// queued for the device: cancel the device entry and resolve.
    ServerFirstProbe(usize),
    /// The device produced its first token while the request was still
    /// queued for server admission: cancel the server entry and resolve.
    DeviceFirstProbe(usize),
    /// Periodic autoscaler evaluation tick (only scheduled when a
    /// scaling policy is attached).
    AutoscaleEval,
    /// Cold shard `.0` finished loading its model: unfreeze its pool and
    /// admit anything already queued on it.
    ShardWarm(usize),
    /// Injected failure: force shard `.0` into Draining, re-route its
    /// queued streams, and let in-flight streams finish (connection
    /// draining). No-op on an already draining/retired/unprovisioned
    /// shard.
    Outage(usize),
    /// Request `.0`'s migrated stream (re-prefilled onto a target shard
    /// under [`MigrationTargeting::ShardTargeted`]) ended: release its
    /// occupancy on that shard and retire its work estimate.
    MigrationRelease(usize),
    /// Continuous-batching scheduling tick: replenish every live
    /// shard's prompt-token admission budget and admit queued prefills
    /// FIFO while it lasts. Only scheduled under
    /// [`BatchingMode::Continuous`]; reschedules itself until every
    /// request has resolved.
    BatchTick,
}

impl<'a> FleetSim<'a> {

    pub(super) fn push(&mut self, time: f64, kind: EvKind) {
        self.queue.push(time, kind);
    }

    /// Mark shard `s` stale in the incremental balancer index (no-op
    /// when the configured balancer keeps none). Called wherever a
    /// shard's occupancy, queue depth, outstanding work, or lifecycle
    /// phase changes, so the next pick's flush sees fresh leaves.
    pub(super) fn touch_shard(&mut self, s: usize) {
        if let Some(idx) = &mut self.shard_index {
            idx.mark(s);
        }
    }

    /// Request `i`, borrowed for the trace lifetime (decoupled from
    /// `&self`, so the loop can mutate simulator state while holding it).
    pub(super) fn req(&self, i: usize) -> &'a crate::trace::Request {
        &self.trace.requests[i]
    }

    /// Spacing of `AutoscaleEval` events: the configured pool interval,
    /// or under disaggregation the minimum over the pools that carry a
    /// scaling policy (one shared tick evaluates both pools). `None`
    /// when no policy is attached — no events are scheduled at all.
    pub(super) fn autoscale_interval(&self) -> Option<f64> {
        let prefill = if self.scaler.is_some() {
            Some(
                self.autoscale
                    .as_ref()
                    .expect("scaler implies autoscale config")
                    .eval_interval,
            )
        } else {
            None
        };
        let decode = if self.decode_scaler.is_some() {
            Some(
                self.decode_autoscale
                    .as_ref()
                    .expect("decode scaler implies decode autoscale config")
                    .eval_interval,
            )
        } else {
            None
        };
        match (prefill, decode) {
            (Some(p), Some(d)) => Some(p.min(d)),
            (Some(p), None) => Some(p),
            (None, d) => d,
        }
    }

    pub(super) fn run(mut self) -> FleetOutcome {
        // Fork per-request RNG streams in trace order (not event order):
        // this pins the root RNG sequence to the trace, matching the
        // legacy engine draw-for-draw. The streams live in the arena and
        // are consumed in place — pre-draw at arrival, resolve later —
        // without the per-request clone the loop used to pay.
        let trace = self.trace;
        let mut root = Rng::new(self.scenario.cfg.seed);
        self.arena.rng = trace.requests.iter().map(|r| root.fork(r.id)).collect();
        for (i, req) in trace.requests.iter().enumerate() {
            self.push(req.arrival, EvKind::Arrival(i));
        }
        // Shard lifetimes (and the report's horizon) are measured from
        // the first arrival.
        self.t0 = trace.requests.first().map_or(0.0, |r| r.arrival);
        for sh in &mut self.shards {
            sh.created_at = self.t0;
        }
        self.record_timeline(self.t0);
        // Outage times are relative to the first arrival. Scheduling them
        // before the first autoscaler evaluation gives outage events the
        // lower sequence number at any shared timestamp, so an outage
        // always fires before an autoscaler evaluation scheduled for the
        // same instant (arrivals, pushed first of all, still precede
        // both — a request arriving exactly at the outage instant is
        // balanced, then immediately re-routed with the rest of the
        // queue).
        if !trace.requests.is_empty() {
            // By index, not by cloned list: `ShardOutage` is `Copy`, so
            // the schedule loop allocates nothing.
            for idx in 0..self.fleet.outages.len() {
                let o = self.fleet.outages[idx];
                if o.at.is_finite() {
                    self.push(self.t0 + o.at.max(0.0), EvKind::Outage(idx));
                }
            }
        }
        if !trace.requests.is_empty() {
            if let Some(interval) = self.autoscale_interval() {
                self.push(self.t0 + interval, EvKind::AutoscaleEval);
            }
        }
        if let Some(tick) = self.fleet.batching.tick_interval() {
            if !trace.requests.is_empty() {
                self.push(self.t0 + tick, EvKind::BatchTick);
            }
        }

        while let Some((time, kind)) = self.queue.pop() {
            // Autoscaler/failure bookkeeping (evaluation ticks, warm-ups,
            // outage injections) does not advance the workload horizon: a
            // cold start completing after the last token would otherwise
            // dilute utilization and over-bill shard-seconds for every
            // surviving shard. Work a warm-up *admits* still lands in the
            // horizon through its own resolve/release events.
            let bookkeeping = matches!(
                kind,
                EvKind::AutoscaleEval
                    | EvKind::ShardWarm(_)
                    | EvKind::Outage(_)
                    | EvKind::BatchTick
            );
            // Superseded release events — paged preemption/failover and
            // iteration-level repricing both re-time a stream's release
            // by pushing a later (or earlier) event — are dropped
            // *before* the horizon update: a stale timestamp is not a
            // workload time, and honoring it would overstate the
            // horizon whenever repricing shrank a stream (the drain
            // direction). Only the event whose timestamp matches the
            // current booking fires, and only once, so a slot never
            // double-frees.
            if let EvKind::ServerRelease(i) = kind {
                if self.release_guard_active()
                    && (self.kv_release_done[i]
                        || time.total_cmp(&self.kv_release_at[i]) != Ordering::Equal)
                {
                    continue;
                }
            }
            if time.is_finite() && !bookkeeping {
                self.horizon = self.horizon.max(time);
            }
            match kind {
                EvKind::Arrival(i) => {
                    let req = self.req(i);
                    // Arrivals fire in trace order (pushed first, over
                    // nondecreasing times), so the pre-draw column grows
                    // densely.
                    debug_assert_eq!(i, self.arena.pre.len(), "arrival out of trace order");
                    let pre = pre_draw(
                        req,
                        self.policy,
                        &self.scenario.server,
                        &self.scenario.device,
                        &mut self.arena.rng[i],
                    );
                    let needs_server = pre.decision.uses_server();
                    let needs_device = pre.decision.uses_device();
                    self.arena.pre.push(pre);
                    self.arena.needs_server[i] = needs_server;
                    self.arena.needs_device[i] = needs_device;
                    if needs_server {
                        // `assign_shard` may shrink the admission charge
                        // to the uncached prompt suffix (paged-KV prefix
                        // hit), so the server charge reads *after* it.
                        let s = self.assign_shard(i, time);
                        let tokens = self.server_tokens[i];
                        if self.shards[s].pool.acquire(i, tokens) {
                            self.on_server_admit(i, time);
                        }
                        self.touch_shard(s);
                    }
                    if needs_device
                        && (!self.fleet.device_queueing
                            || self.device_pool.acquire(i, self.prompt_tokens[i]))
                    {
                        self.on_device_grant(i, time);
                    }
                    self.try_resolve(i, time);
                }
                EvKind::ServerRelease(i) => {
                    // Stale (superseded) releases were dropped before
                    // the horizon update above; this one is valid. Mark
                    // it done so preemption, failover, and repricing
                    // stop considering the stream.
                    if self.release_guard_active() {
                        self.kv_release_done[i] = true;
                    }
                    let s = self.shard_of[i].expect("released requests are assigned");
                    // Iteration-level pricing: the stream's delivered
                    // record finalizes from its (possibly re-stamped)
                    // generation timeline only now, when no further
                    // batch change can touch it.
                    self.finalize_stream(i, s);
                    // The stream's KV pages free with its slot — before
                    // the pool release below, so the admit-next scan
                    // sees the freed pages.
                    let held = self.kv_pages_held[i];
                    if held > 0 {
                        self.kv_pages_held[i] = 0;
                        if let Some(g) = self.shards[s].pool.kv_mut() {
                            g.free(held);
                        }
                    }
                    if self.fleet.batching.is_paged() {
                        self.kv_live[s].retain(|&j| j != i);
                    }
                    // The slot holder's service ends here — only now does
                    // its work estimate leave the LeastWork signal.
                    let sample = self.arena.pre[i]
                        .server_sample
                        .expect("server users have a sample");
                    self.shards[s].work -= sample;
                    let next = self
                        .shards[s]
                        .pool
                        .release(&self.server_cancelled, &self.server_tokens);
                    self.touch_shard(s);
                    if let Some(j) = next {
                        self.on_server_admit(j, time);
                        self.try_resolve(j, time);
                    }
                    self.record_batch(s, time);
                    self.maybe_retire(s, time);
                }
                EvKind::DeviceRelease => {
                    let next = self
                        .device_pool
                        .release(&self.device_cancelled, &self.prompt_tokens);
                    if let Some(j) = next {
                        self.on_device_grant(j, time);
                        self.try_resolve(j, time);
                    }
                }
                EvKind::ServerFirstProbe(i) => {
                    let pending = !self.device_cancelled[i]
                        && !self.arena.resolved[i]
                        && self.arena.device_grant[i].is_none();
                    if pending {
                        // The server answered first: leave the device
                        // queue (`device_grant` is None, so with device
                        // queueing on the request is sitting in it).
                        self.device_cancelled[i] = true;
                        if self.fleet.device_queueing {
                            let tokens = self.prompt_tokens[i];
                            self.device_pool.cancel_queued(tokens);
                        }
                        self.try_resolve(i, time);
                    }
                }
                EvKind::DeviceFirstProbe(i) => {
                    let pending = !self.server_cancelled[i]
                        && !self.arena.resolved[i]
                        && self.arena.server_admit[i].is_none();
                    if pending {
                        // The device answered first: abandon the admission
                        // queue (the provider still bills the dispatched
                        // prompt; see `resolve_request`). `server_admit`
                        // is None, so the entry is sitting in its shard's
                        // queue.
                        self.server_cancelled[i] = true;
                        let s = self.shard_of[i].expect("server-bound requests are assigned");
                        let tokens = self.server_tokens[i];
                        self.shards[s].pool.cancel_queued(tokens);
                        self.touch_shard(s);
                        self.try_resolve(i, time);
                        // A draining shard whose last live entry was just
                        // cancelled can retire now.
                        self.maybe_retire(s, time);
                    }
                }
                EvKind::AutoscaleEval => {
                    self.autoscale_eval(time);
                    if self.resolved_count < trace.len() {
                        let interval = self
                            .autoscale_interval()
                            .expect("eval events imply a scaling policy");
                        self.push(time + interval, EvKind::AutoscaleEval);
                    }
                }
                EvKind::ShardWarm(s) => self.warm_shard(s, time),
                EvKind::Outage(idx) => {
                    let shard = self.fleet.outages[idx].shard;
                    self.inject_outage(shard, time);
                }
                EvKind::MigrationRelease(i) => {
                    let (s, real_slot, work, booked_at) = self.migration_booking[i]
                        .take()
                        .expect("migration release implies a booking");
                    self.shards[s].work -= work;
                    // Booked occupancy splits by where it sat: real
                    // slots bill into busy-seconds (within capacity),
                    // batch joins into over-commit seconds — keeping
                    // utilization a within-capacity ratio.
                    let held = (time - booked_at).max(0.0);
                    if real_slot {
                        self.shards[s].busy += held;
                    } else {
                        self.shards[s].overcommit_seconds += held;
                    }
                    // KV pages booked for the migrated-in stream free
                    // with its occupancy (before the admit-next scan).
                    let pages = self.kv_mig_pages[i];
                    if pages > 0 {
                        self.kv_mig_pages[i] = 0;
                        if let Some(g) = self.shards[s].pool.kv_mut() {
                            g.free(pages);
                        }
                    }
                    let next = if real_slot {
                        self.shards[s]
                            .pool
                            .release(&self.server_cancelled, &self.server_tokens)
                    } else {
                        self.shards[s]
                            .pool
                            .release_overflow(&self.server_cancelled, &self.server_tokens)
                    };
                    self.touch_shard(s);
                    if let Some(j) = next {
                        self.on_server_admit(j, time);
                        self.try_resolve(j, time);
                    }
                    self.record_batch(s, time);
                    self.maybe_retire(s, time);
                }
                EvKind::BatchTick => {
                    let paged = self.fleet.batching.is_paged();
                    let shard_count = self.shards.len();
                    for s in 0..shard_count {
                        // Retired shards are gone; cold (frozen) shards
                        // cannot admit, so ticking them would only
                        // inflate `prompt_token_capacity` with budget
                        // nothing could use — they start ticking once
                        // warm, with their initial allotment intact.
                        if self.shards[s].phase == LifecyclePhase::Retired
                            || self.shards[s].pool.frozen
                        {
                            continue;
                        }
                        self.shards[s].pool.tick();
                        if paged {
                            // Decode growth first, then preemption if
                            // growth blew past the pool — so admission
                            // below sees the true free-page count.
                            self.kv_tick_shard(s, time);
                        }
                        while let Some(j) = self
                            .shards[s]
                            .pool
                            .try_admit(&self.server_cancelled, &self.server_tokens)
                        {
                            self.on_server_admit(j, time);
                            self.try_resolve(j, time);
                        }
                        self.touch_shard(s);
                    }
                    if self.resolved_count < trace.len() {
                        let interval = self
                            .fleet
                            .batching
                            .tick_interval()
                            .expect("ticks imply a tick-scheduled batching mode");
                        self.push(time + interval, EvKind::BatchTick);
                    }
                }
            }
        }

        let records: Vec<RequestRecord> = self
            .records
            .into_iter()
            .map(|r| r.expect("every request resolves"))
            .collect();
        // Horizon is measured from the first arrival, not absolute time
        // zero, so traces with a delayed start (e.g. session ramp-up) do
        // not dilute utilization with an idle prefix.
        let t0 = self.t0;
        let end = self.horizon.max(t0);
        // Fleet-level aggregates derive from the per-shard accounting —
        // one source of truth (Summary sorts internally, so the shard
        // concatenation order is irrelevant).
        let mut all_delays: Vec<f64> = Vec::new();
        let mut server_busy = 0.0;
        let mut shard_seconds = 0.0;
        let mut release_underflows = self.device_pool.underflows;
        let mut prefix_hits = 0u64;
        let mut prefix_lookups = 0u64;
        let mut prefix_evictions = 0u64;
        let shard_loads: Vec<ShardLoad> = self
            .shards
            .iter()
            .map(|s| {
                all_delays.extend_from_slice(&s.delays);
                server_busy += s.busy;
                release_underflows += s.pool.underflows;
                // Retirement can be stamped by a post-horizon autoscaler
                // tick; clamp so draining never bills MORE than staying
                // warm to the end of the run.
                let shard_end = s.retired_at.unwrap_or(end).min(end);
                let lifetime = (shard_end - s.created_at).max(0.0);
                shard_seconds += lifetime;
                let (prompt_tokens_admitted, prompt_token_capacity) = s.pool.token_totals();
                let (kv_pages_peak, kv_pages_total) = match s.pool.kv() {
                    Some(g) => {
                        let (h, l) = g.prefix_stats();
                        prefix_hits += h;
                        prefix_lookups += l;
                        prefix_evictions += g.prefix_evictions();
                        (g.peak_pages(), g.pages_total())
                    }
                    None => (0, 0),
                };
                ShardLoad {
                    queue_delay: Summary::of(&s.delays),
                    busy_seconds: s.busy,
                    overcommit_seconds: s.overcommit_seconds,
                    admitted: s.admitted,
                    slots: s.pool.cap,
                    migrated_in: s.migrated_in,
                    role: s.role,
                    handoff_in: s.handoff_in,
                    lifetime_seconds: lifetime,
                    peak_in_use: s.pool.peak_in_use,
                    prompt_tokens_admitted,
                    prompt_token_capacity,
                    kv_pages_peak,
                    kv_pages_total,
                }
            })
            .collect();
        // Timeline and scale-event timestamps are reported relative to
        // the first arrival, like the horizon.
        let rel = |t: f64| (t - t0).max(0.0);
        let shard_timeline = self
            .timeline
            .iter()
            .map(|s| ShardCountSample {
                time: rel(s.time),
                ..*s
            })
            .collect();
        let scale_events = self
            .scale_events
            .iter()
            .map(|e| ScaleEvent {
                time: rel(e.time),
                ..*e
            })
            .collect();
        let batch_timeline = self
            .batch_samples
            .iter()
            .map(|b| BatchSample {
                time: rel(b.time),
                ..*b
            })
            .collect();
        let load = LoadReport {
            server_queue_delay: Summary::of(&all_delays),
            device_queue_delay: Summary::of(&self.device_delays),
            server_busy_seconds: server_busy,
            device_busy_seconds: self.device_busy,
            horizon: (self.horizon - t0).max(0.0),
            server_slots: self.fleet.server_slots,
            shards: shard_loads,
            shard_timeline,
            scale_events,
            cold_start_seconds: self.cold_start_seconds,
            shard_seconds,
            events_processed: self.queue.pushed(),
            migration_targeted: self.migration_targeted,
            migration_fallbacks: self.migration_fallbacks,
            outage_requeues: self.outage_requeues,
            release_underflows,
            batch_timeline,
            prefix_hits,
            prefix_lookups,
            kv_preemptions: self.kv_preemptions,
            kv_forced_reprefills: self.kv_forced_reprefills,
            reprice_events: self.reprice_events,
            reprice_stretch_seconds: self.reprice_stretch_seconds,
            reprice_shrink_seconds: self.reprice_shrink_seconds,
            prefix_evictions,
            handoff_count: self.handoff_count,
            kv_transfer_seconds: self.kv_transfer_seconds,
            handoff_fallbacks: self.handoff_fallbacks,
        };
        FleetOutcome { records, load }
    }

}
